//! Benchmark harness (criterion is unavailable offline).
//!
//! Provides warm-up, repetition, robust summary statistics, and the
//! markdown/CSV emitters every `rust/benches/*.rs` target uses to print the
//! paper-shaped tables (Figure 2 rows, Table 2 rows, ablations).
//!
//! Timing protocol per case: `warmup` untimed runs, then `reps` timed runs;
//! we report mean, ±2σ (the paper's band), min, and median.

use std::fs;
use std::io::Write as _;
use std::path::Path;

use crate::util::profile::{Phase, Profiler};
use crate::util::stats::OnlineStats;
use crate::util::timer::{fmt_duration, Timer};

/// True when the current process runs as a CI smoke check: the
/// criterion-compatible `--test` / `--smoke` flags or
/// `SIMOPT_BENCH_SMOKE=1`.  The single source of truth — the bench
/// binaries (via `benches/common`) shrink their workloads on it, and
/// [`Bench::to_json`] stamps it into the telemetry record so trajectory
/// tooling can separate smoke runs from real timings.
pub fn smoke_mode() -> bool {
    std::env::args().any(|a| a == "--test" || a == "--smoke")
        || matches!(std::env::var("SIMOPT_BENCH_SMOKE").as_deref(), Ok("1"))
}

/// One measured case (a row in a bench table).
#[derive(Debug, Clone)]
pub struct Measurement {
    pub label: String,
    pub reps: usize,
    pub mean_s: f64,
    pub std_s: f64,
    pub min_s: f64,
    pub median_s: f64,
    /// Per-phase attribution of the measured work (DESIGN.md §15); empty
    /// for cases whose workload doesn't report a profile.
    pub profile: Profiler,
}

impl Measurement {
    pub fn band2(&self) -> (f64, f64) {
        (self.mean_s - 2.0 * self.std_s, self.mean_s + 2.0 * self.std_s)
    }
}

/// Collects measurements and renders them.
pub struct Bench {
    pub name: String,
    warmup: usize,
    reps: usize,
    rows: Vec<Measurement>,
}

impl Bench {
    pub fn new(name: &str) -> Self {
        // Keep default effort low on the 1-core CI box; benches can override.
        Bench { name: name.to_string(), warmup: 1, reps: 5, rows: Vec::new() }
    }

    pub fn warmup(mut self, w: usize) -> Self {
        self.warmup = w;
        self
    }

    pub fn reps(mut self, r: usize) -> Self {
        self.reps = r;
        self
    }

    /// Time `f` under the harness protocol and record a row.
    pub fn case<F: FnMut()>(&mut self, label: &str, mut f: F) -> &Measurement {
        for _ in 0..self.warmup {
            f();
        }
        let mut samples = Vec::with_capacity(self.reps);
        let mut stats = OnlineStats::new();
        for _ in 0..self.reps.max(1) {
            let t = Timer::start();
            f();
            let s = t.elapsed_s();
            samples.push(s);
            stats.push(s);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = samples[samples.len() / 2];
        self.rows.push(Measurement {
            label: label.to_string(),
            reps: self.reps,
            mean_s: stats.mean(),
            std_s: stats.std(),
            min_s: stats.min(),
            median_s: median,
            profile: Profiler::new(),
        });
        self.rows.last().unwrap()
    }

    /// Record an externally-timed sample set (e.g. per-epoch times collected
    /// inside a driver).
    pub fn record(&mut self, label: &str, samples_s: &[f64]) -> &Measurement {
        self.record_profiled(label, samples_s, Profiler::new())
    }

    /// [`Bench::record`] plus the workload's per-phase attribution
    /// (DESIGN.md §15), so `BENCH_*.json` telemetry carries where the
    /// measured seconds went — not just how many there were.
    pub fn record_profiled(&mut self, label: &str, samples_s: &[f64],
                           profile: Profiler) -> &Measurement {
        let mut stats = OnlineStats::new();
        for &s in samples_s {
            stats.push(s);
        }
        let mut sorted = samples_s.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = if sorted.is_empty() { f64::NAN } else { sorted[sorted.len() / 2] };
        self.rows.push(Measurement {
            label: label.to_string(),
            reps: samples_s.len(),
            mean_s: stats.mean(),
            std_s: stats.std(),
            min_s: stats.min(),
            median_s: median,
            profile,
        });
        self.rows.last().unwrap()
    }

    pub fn rows(&self) -> &[Measurement] {
        &self.rows
    }

    pub fn find(&self, label: &str) -> Option<&Measurement> {
        self.rows.iter().find(|m| m.label == label)
    }

    /// Markdown table in the shape the paper's figures report:
    /// label, mean, ±2σ band, min, median.
    pub fn to_markdown(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("### {}\n\n", self.name));
        out.push_str("| case | mean | ±2σ | min | median | reps |\n");
        out.push_str("|---|---|---|---|---|---|\n");
        for m in &self.rows {
            out.push_str(&format!(
                "| {} | {} | ±{} | {} | {} | {} |\n",
                m.label,
                fmt_duration(m.mean_s),
                fmt_duration(2.0 * m.std_s),
                fmt_duration(m.min_s),
                fmt_duration(m.median_s),
                m.reps
            ));
        }
        out
    }

    pub fn to_csv(&self) -> String {
        let mut out = String::from("label,reps,mean_s,std_s,min_s,median_s");
        for p in Phase::ALL {
            out.push_str(&format!(",phase_{}_s", p));
        }
        out.push('\n');
        for m in &self.rows {
            out.push_str(&format!(
                "{},{},{:.9},{:.9},{:.9},{:.9}",
                m.label, m.reps, m.mean_s, m.std_s, m.min_s, m.median_s
            ));
            for p in Phase::ALL {
                out.push_str(&format!(",{:.9}", m.profile.get(p)));
            }
            out.push('\n');
        }
        out
    }

    /// Machine-readable run record (`BENCH_<name>.json`) — the per-commit
    /// telemetry the CI bench-smoke matrix uploads as an artifact, so a
    /// perf trajectory can be assembled across commits.  Commit / run
    /// identifiers are taken from the standard CI environment when
    /// present.
    pub fn to_json(&self) -> String {
        use crate::util::json::{arr, num, obj, s};
        let cases = self
            .rows
            .iter()
            .map(|m| {
                obj(vec![
                    ("label", s(&m.label)),
                    ("reps", num(m.reps as f64)),
                    ("mean_s", num(m.mean_s)),
                    ("std_s", num(m.std_s)),
                    ("min_s", num(m.min_s)),
                    ("median_s", num(m.median_s)),
                    ("per_phase", m.profile.to_json()),
                ])
            })
            .collect();
        let mut top = vec![("bench", s(&self.name))];
        if let Ok(sha) = std::env::var("GITHUB_SHA") {
            top.push(("commit", s(&sha)));
        }
        if let Ok(run) = std::env::var("GITHUB_RUN_ID") {
            top.push(("ci_run", s(&run)));
        }
        top.push(("smoke", crate::util::json::Value::Bool(smoke_mode())));
        top.push(("cases", arr(cases)));
        obj(top).to_string_pretty()
    }

    /// Print markdown to stdout and persist CSV + JSON under
    /// `results/bench/`.
    pub fn finish(&self) {
        println!("{}", self.to_markdown());
        let dir = Path::new("results").join("bench");
        if fs::create_dir_all(&dir).is_ok() {
            let path = dir.join(format!("{}.csv", self.name));
            if let Ok(mut f) = fs::File::create(&path) {
                let _ = f.write_all(self.to_csv().as_bytes());
                println!("[bench] wrote {}", path.display());
            }
            let jpath = dir.join(format!("BENCH_{}.json", self.name));
            if let Ok(mut f) = fs::File::create(&jpath) {
                let _ = f.write_all(self.to_json().as_bytes());
                println!("[bench] wrote {}", jpath.display());
            }
        }
    }
}

/// Speedup helper for the paper's headline "GPU is 3-6× faster" rows.
pub fn speedup(baseline: &Measurement, accelerated: &Measurement) -> f64 {
    if accelerated.mean_s == 0.0 {
        return f64::INFINITY;
    }
    baseline.mean_s / accelerated.mean_s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn case_records_row() {
        let mut b = Bench::new("t").warmup(0).reps(3);
        b.case("noop", || {});
        assert_eq!(b.rows().len(), 1);
        let m = &b.rows()[0];
        assert_eq!(m.reps, 3);
        assert!(m.mean_s >= 0.0);
        assert!(m.min_s <= m.mean_s + 1e-9);
    }

    #[test]
    fn record_external_samples() {
        let mut b = Bench::new("t");
        let m = b.record("ext", &[1.0, 2.0, 3.0]).clone();
        assert!((m.mean_s - 2.0).abs() < 1e-12);
        assert!((m.median_s - 2.0).abs() < 1e-12);
        assert_eq!(m.min_s, 1.0);
    }

    #[test]
    fn markdown_and_csv_shapes() {
        let mut b = Bench::new("shape");
        b.record("a", &[0.5, 0.5]);
        let md = b.to_markdown();
        assert!(md.contains("| a |"));
        assert!(md.contains("±"));
        let csv = b.to_csv();
        assert!(csv.lines().count() == 2);
        assert!(csv.starts_with("label,"));
    }

    #[test]
    fn json_record_shape() {
        let mut b = Bench::new("jshape");
        b.record("case_a", &[1.0, 3.0]);
        let v = crate::util::json::Value::parse(&b.to_json()).unwrap();
        assert_eq!(v.get("bench").and_then(|x| x.as_str()), Some("jshape"));
        let cases = v.get("cases").unwrap().as_arr().unwrap();
        assert_eq!(cases.len(), 1);
        assert_eq!(cases[0].get("label").and_then(|x| x.as_str()),
                   Some("case_a"));
        assert_eq!(cases[0].get("mean_s").and_then(|x| x.as_f64()), Some(2.0));
        // every case carries a per_phase object, empty when unprofiled
        assert!(cases[0].get("per_phase").unwrap().as_obj().unwrap()
                        .is_empty());
    }

    #[test]
    fn profiled_record_reaches_telemetry() {
        let mut b = Bench::new("pshape");
        let mut prof = Profiler::new();
        prof.add(Phase::Compute, 0.75);
        prof.add(Phase::Dispatch, 0.25);
        b.record_profiled("case_p", &[1.0], prof);
        let v = crate::util::json::Value::parse(&b.to_json()).unwrap();
        let cases = v.get("cases").unwrap().as_arr().unwrap();
        let pp = cases[0].get("per_phase").unwrap();
        assert_eq!(pp.get("compute").and_then(|x| x.as_f64()), Some(0.75));
        assert_eq!(pp.get("dispatch").and_then(|x| x.as_f64()), Some(0.25));
        let csv = b.to_csv();
        assert!(csv.lines().next().unwrap().contains(",phase_compute_s"));
        assert!(csv.lines().nth(1).unwrap().ends_with(
            ",0.250000000,0.750000000,0.000000000,0.000000000,\
             0.000000000,0.000000000"), "{}", csv);
    }

    #[test]
    fn speedup_ratio() {
        let mut b = Bench::new("s");
        let slow = b.record("slow", &[4.0]).clone();
        let fast = b.record("fast", &[1.0]).clone();
        assert!((speedup(&slow, &fast) - 4.0).abs() < 1e-12);
    }

    #[test]
    fn find_by_label() {
        let mut b = Bench::new("f");
        b.record("x", &[1.0]);
        assert!(b.find("x").is_some());
        assert!(b.find("y").is_none());
    }
}
