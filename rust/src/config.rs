//! Typed configuration shared by the CLI, coordinator, and benches.

use std::fmt;

/// The registered scenarios.  The enum is the cheap `Copy` handle threaded
/// through specs and reports; everything task-specific behind it — names,
/// defaults, validation, backends, drivers, artifact requirements — lives
/// in [`crate::tasks::registry`], so `parse`/`as_str`/`all` are registry
/// lookups and a new scenario is one variant plus one registration.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TaskKind {
    /// §3.1 mean-variance portfolio (Frank-Wolfe, Algorithm 1)
    MeanVariance,
    /// §3.2 multi-product newsvendor (Frank-Wolfe + LP LMO, Algorithm 2)
    Newsvendor,
    /// §3.3 binary classification (SQN, Algorithms 3-4)
    Classification,
    /// Mean-CVaR portfolio (Rockafellar–Uryasev smoothed CVaR, Frank-Wolfe
    /// over the capped simplex × VaR box; DESIGN.md §12)
    MeanCvar,
}

impl TaskKind {
    pub fn parse(s: &str) -> Option<Self> {
        crate::tasks::registry::parse(s)
    }

    pub fn as_str(&self) -> &'static str {
        crate::tasks::registry::get(*self).name()
    }

    /// Every registered task, in registration order.
    pub fn all() -> Vec<TaskKind> {
        crate::tasks::registry::kinds()
    }
}

impl fmt::Display for TaskKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Execution model — the paper's CPU/GPU axis (DESIGN.md §2).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BackendKind {
    /// Sequential scalar Rust — the paper's "CPU processes samples
    /// individually" arm.
    Native,
    /// Thread-pooled native (ablation A3: CPU parallelism without
    /// vectorized fusion).
    NativePar,
    /// AOT-compiled XLA artifacts via PJRT — the vectorized "GPU-style" arm.
    Xla,
}

impl BackendKind {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "native" | "cpu" | "seq" => Some(BackendKind::Native),
            "native_par" | "native-par" | "par" => Some(BackendKind::NativePar),
            "xla" | "gpu" | "pjrt" => Some(BackendKind::Xla),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::NativePar => "native_par",
            BackendKind::Xla => "xla",
        }
    }
}

impl fmt::Display for BackendKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// How the replication axis of an experiment executes (DESIGN.md §11/§13).
///
/// Batched and sequential execution are bit-for-bit identical per
/// replication (same `StreamTree` subtrees, same per-row arithmetic); the
/// mode only changes how the work is dispatched.  Shard count is part of
/// the batched plan: `Batched { shards: 1 }` is the single-panel engine,
/// `shards: S` partitions the R replication rows into S contiguous shards
/// through `backend::plane` — still bit-identical, only buffer ownership
/// and dispatch granularity move.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ExecMode {
    /// Let the coordinator pick: batched (unsharded) for multi-replication
    /// native runs, sequential otherwise (XLA batch artifacts are opt-in).
    Auto,
    /// One dispatch per replication per step (the original protocol).
    Sequential,
    /// All replications advance through the shard-aware panel plane in one
    /// call per step: S inner batch backends over contiguous row shards
    /// (`--shards`, DESIGN.md §13).
    Batched { shards: usize },
}

impl ExecMode {
    pub fn parse(s: &str) -> Option<Self> {
        match s.to_ascii_lowercase().as_str() {
            "auto" => Some(ExecMode::Auto),
            "seq" | "sequential" => Some(ExecMode::Sequential),
            "batch" | "batched" => Some(ExecMode::Batched { shards: 1 }),
            _ => None,
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            ExecMode::Auto => "auto",
            ExecMode::Sequential => "sequential",
            ExecMode::Batched { .. } => "batched",
        }
    }

    /// Shard count of the plan (1 for every non-sharded mode).
    pub fn shards(&self) -> usize {
        match self {
            ExecMode::Batched { shards } => *shards,
            _ => 1,
        }
    }

    /// Reassemble a mode from its wire encoding: the `as_str` mode name
    /// plus an explicit shard count (`ExperimentSpec::to_json` splits the
    /// two so the spec grammar stays flat).  Non-batched modes carry no
    /// shard plan, so anything but `shards == 1` is rejected rather than
    /// silently dropped — a spec that *meant* `--shards 3` must not hash
    /// or run as an unsharded plan.
    pub fn from_parts(mode: &str, shards: usize) -> Option<ExecMode> {
        match ExecMode::parse(mode)? {
            ExecMode::Batched { .. } => Some(ExecMode::Batched { shards }),
            m if shards == 1 => Some(m),
            _ => None,
        }
    }
}

impl fmt::Display for ExecMode {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Opt-in adaptive replication budget (DESIGN.md §14): at every
/// `check_every`-th epoch the batched plan compares the live rows of the
/// shared `[R × n]` objective panel and freezes replications whose current
/// objective trails the best live row by more than `gap` (relative to the
/// best row's magnitude) — the trace-gap rule.  Once every surviving row's
/// objective has moved by at most `tol` (relative) since the previous
/// checkpoint, the run stops early.  Frozen rows stay in the panel (masked,
/// not resliced — shard shapes never change); their traces simply stop.
/// Off by default: a spec without a budget runs all R replications for all
/// epochs and keeps the bitwise seq==batch invariant.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BudgetPolicy {
    /// Epoch-checkpoint cadence (must be > 0).
    pub check_every: usize,
    /// Relative trace gap beyond which a replication freezes.
    pub gap: f64,
    /// Relative per-checkpoint improvement below which a survivor counts
    /// as converged (early stop once ALL survivors converge).
    pub tol: f64,
}

impl BudgetPolicy {
    /// A policy checking every `check_every` epochs with the default
    /// gap/tolerance.
    pub fn every(check_every: usize) -> Self {
        BudgetPolicy { check_every, gap: 0.25, tol: 1e-6 }
    }
}

/// Paper §4.1 parameters with this repo's defaults (DESIGN.md §10 documents
/// the scaling deviations).
#[derive(Debug, Clone)]
pub struct TaskParams {
    /// Problem dimension (assets / products / features).
    pub size: usize,
    /// Samples per gradient estimate (panel rows).
    pub samples: usize,
    /// Frank-Wolfe steps between resampling (Algorithms 1-2 `M`).
    pub m_inner: usize,
    /// Epochs (Algorithms 1-2 `K`) or SQN iterations (Algorithm 3 `k`).
    pub iters: usize,
    /// SQN minibatch `b`.
    pub batch: usize,
    /// SQN Hessian batch `b_H`.
    pub hbatch: usize,
    /// SQN memory `M`.
    pub memory: usize,
    /// SQN update spacing `L`.
    pub l_every: usize,
    /// SQN step scale β (α_k = β/k).
    pub beta: f32,
    /// Newsvendor resource count.
    pub resources: usize,
    /// Newsvendor capacity tightness.
    pub tightness: f32,
}

impl TaskParams {
    /// The registered task's §4.1-shaped defaults (a registry lookup).
    pub fn defaults(task: TaskKind, size: usize) -> Self {
        crate::tasks::registry::get(task).default_params(size)
    }
}

/// Default size sweeps per task (the Figure-2 x-axes, scaled per DESIGN §2
/// — a registry lookup).
pub fn default_sizes(task: TaskKind) -> Vec<usize> {
    crate::tasks::registry::get(task).default_sizes()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn task_parse_aliases() {
        assert_eq!(TaskKind::parse("mv"), Some(TaskKind::MeanVariance));
        assert_eq!(TaskKind::parse("Portfolio"), Some(TaskKind::MeanVariance));
        assert_eq!(TaskKind::parse("NV"), Some(TaskKind::Newsvendor));
        assert_eq!(TaskKind::parse("logistic"), Some(TaskKind::Classification));
        assert_eq!(TaskKind::parse("cvar"), Some(TaskKind::MeanCvar));
        assert_eq!(TaskKind::parse("CV"), Some(TaskKind::MeanCvar));
        assert_eq!(TaskKind::parse("wat"), None);
    }

    #[test]
    fn backend_parse_aliases() {
        assert_eq!(BackendKind::parse("cpu"), Some(BackendKind::Native));
        assert_eq!(BackendKind::parse("gpu"), Some(BackendKind::Xla));
        assert_eq!(BackendKind::parse("native_par"), Some(BackendKind::NativePar));
        assert_eq!(BackendKind::parse(""), None);
    }

    #[test]
    fn display_roundtrip() {
        for t in TaskKind::all() {
            assert_eq!(TaskKind::parse(t.as_str()), Some(t));
        }
        for b in [BackendKind::Native, BackendKind::NativePar, BackendKind::Xla] {
            assert_eq!(BackendKind::parse(b.as_str()), Some(b));
        }
        for e in [ExecMode::Auto, ExecMode::Sequential,
                  ExecMode::Batched { shards: 1 }] {
            assert_eq!(ExecMode::parse(e.as_str()), Some(e));
        }
        // a sharded plan renders as its mode; the shard count is carried
        // separately (reports/CLI print it)
        assert_eq!(ExecMode::Batched { shards: 4 }.as_str(), "batched");
    }

    #[test]
    fn exec_mode_aliases() {
        assert_eq!(ExecMode::parse("seq"), Some(ExecMode::Sequential));
        assert_eq!(ExecMode::parse("batch"),
                   Some(ExecMode::Batched { shards: 1 }));
        assert_eq!(ExecMode::parse("Batched"),
                   Some(ExecMode::Batched { shards: 1 }));
        assert_eq!(ExecMode::parse("nope"), None);
    }

    #[test]
    fn exec_mode_from_parts() {
        assert_eq!(ExecMode::from_parts("auto", 1), Some(ExecMode::Auto));
        assert_eq!(ExecMode::from_parts("sequential", 1),
                   Some(ExecMode::Sequential));
        assert_eq!(ExecMode::from_parts("batched", 3),
                   Some(ExecMode::Batched { shards: 3 }));
        assert_eq!(ExecMode::from_parts("batch", 1),
                   Some(ExecMode::Batched { shards: 1 }));
        // a shard count on a non-batched mode is a contradiction, not a
        // default — reject instead of dropping the plan
        assert_eq!(ExecMode::from_parts("auto", 2), None);
        assert_eq!(ExecMode::from_parts("seq", 0), None);
        assert_eq!(ExecMode::from_parts("wat", 1), None);
        // round-trip through (as_str, shards) is identity
        for e in [ExecMode::Auto, ExecMode::Sequential,
                  ExecMode::Batched { shards: 1 },
                  ExecMode::Batched { shards: 4 }] {
            assert_eq!(ExecMode::from_parts(e.as_str(), e.shards()), Some(e));
        }
    }

    #[test]
    fn exec_mode_shard_counts() {
        assert_eq!(ExecMode::Auto.shards(), 1);
        assert_eq!(ExecMode::Sequential.shards(), 1);
        assert_eq!(ExecMode::Batched { shards: 1 }.shards(), 1);
        assert_eq!(ExecMode::Batched { shards: 3 }.shards(), 3);
    }

    #[test]
    fn defaults_sane() {
        let p = TaskParams::defaults(TaskKind::Classification, 256);
        assert_eq!(p.size, 256);
        assert!(p.batch > 0 && p.hbatch > p.batch);
        assert!(p.memory > 0 && p.l_every > 0);
        let p = TaskParams::defaults(TaskKind::Newsvendor, 64);
        assert!(p.resources > 0);
        assert!(p.tightness < 1.0);
        let p = TaskParams::defaults(TaskKind::MeanCvar, 128);
        assert!(p.samples > 0 && p.m_inner > 0);
    }

    #[test]
    fn sweep_sizes_ascending() {
        for t in TaskKind::all() {
            let sizes = default_sizes(t);
            assert!(sizes.windows(2).all(|w| w[0] < w[1]));
        }
    }
}
