//! Frank-Wolfe drivers (paper Algorithms 1 and 2), generic over the
//! execution backend.
//!
//! Task 1's epoch (resample + M steps + analytic LMO) is entirely inside the
//! backend — the XLA arm runs it as ONE device dispatch.  Task 2 interleaves
//! backend gradient estimates with the LP LMO on the host, so the driver
//! owns the loop.

use anyhow::Result;

use crate::backend::{MvBackend, NvBackend};
use crate::rng::StreamTree;
use crate::tasks::newsvendor::NvLmo;
use crate::util::timer::Timer;

use super::schedule::fw_gamma;

/// Objective + timing trace of one optimization run.
#[derive(Debug, Clone, Default)]
pub struct FwTrace {
    /// Empirical objective at the end of each epoch.
    pub objs: Vec<f64>,
    /// Wall-clock seconds per epoch.
    pub epoch_s: Vec<f64>,
}

impl FwTrace {
    pub fn total_s(&self) -> f64 {
        self.epoch_s.iter().sum()
    }
}

/// Algorithm 1: `epochs` fused epochs on any [`MvBackend`].
///
/// `tree` must be the *replication-level* stream tree; epoch panels use
/// paths `[epoch]`.
pub fn run_mv<B: MvBackend + ?Sized>(
    backend: &mut B,
    w0: Vec<f32>,
    epochs: usize,
    tree: &StreamTree,
) -> Result<(Vec<f32>, FwTrace)> {
    let mut w = w0;
    let mut trace = FwTrace::default();
    for k in 0..epochs {
        let key = tree.jax_key(&[k as u64]);
        let t = Timer::start();
        let (w_next, obj) = backend.epoch(&w, k, key)?;
        trace.epoch_s.push(t.elapsed_s());
        trace.objs.push(obj);
        w = w_next;
    }
    Ok((w, trace))
}

/// Algorithm 2: per-iteration gradient (backend) + LP LMO (host) + update,
/// resampling every `m_inner` iterations via the epoch key.
pub fn run_nv<B: NvBackend + ?Sized>(
    backend: &mut B,
    lmo: &mut NvLmo,
    x0: Vec<f32>,
    epochs: usize,
    m_inner: usize,
    tree: &StreamTree,
) -> Result<(Vec<f32>, FwTrace)> {
    let mut x = x0;
    let mut trace = FwTrace::default();
    let mut obj = f64::NAN;
    for k in 0..epochs {
        // one key per epoch ⇒ the backend's panel is frozen for m_inner
        // steps (Algorithm 2 line 5), counter-based RNG guarantees identity
        let key = tree.jax_key(&[k as u64]);
        let t = Timer::start();
        for m in 0..m_inner {
            let (g, o) = backend.grad_obj(&x, key)?;
            obj = o;
            let s = lmo.solve(&g)?;
            let gamma = fw_gamma(k, m, m_inner);
            crate::linalg::vector::fw_update(&mut x, &s, gamma);
        }
        trace.epoch_s.push(t.elapsed_s());
        trace.objs.push(obj);
    }
    Ok((x, trace))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::{NativeMv, NativeNv, NativeMode};
    use crate::sim::{AssetUniverse, NewsvendorInstance};
    use crate::tasks::mean_variance::in_simplex;

    #[test]
    fn mv_driver_descends_and_stays_feasible() {
        let tree = StreamTree::new(11);
        let u = AssetUniverse::generate(&tree, 48);
        let mut backend = NativeMv::new(u.clone(), 32, 10,
                                        NativeMode::Sequential);
        let w0 = vec![1.0 / 48.0; 48];
        let (w, trace) = run_mv(&mut backend, w0.clone(), 12,
                                &tree.subtree(&[0])).unwrap();
        assert_eq!(trace.objs.len(), 12);
        assert_eq!(trace.epoch_s.len(), 12);
        assert!(in_simplex(&w, 1e-4));
        // the tail of the trace must improve on the start (each epoch's
        // objective is estimated on a fresh panel, so allow MC noise)
        let first = trace.objs[0];
        let last = *trace.objs.last().unwrap();
        assert!(last <= first + 0.02 * first.abs(), "{} !<= {}", last, first);
        // and beat the uniform portfolio's exact objective
        assert!(u.exact_objective(&w) < u.exact_objective(&w0));
    }

    #[test]
    fn mv_driver_reproducible() {
        let tree = StreamTree::new(12);
        let u = AssetUniverse::generate(&tree, 16);
        let w0 = vec![1.0 / 16.0; 16];
        let run = |_i| {
            let mut b = NativeMv::new(u.clone(), 8, 5, NativeMode::Sequential);
            run_mv(&mut b, w0.clone(), 5, &tree.subtree(&[3])).unwrap()
        };
        let (w1, t1) = run(0);
        let (w2, t2) = run(1);
        assert_eq!(w1, w2);
        assert_eq!(t1.objs, t2.objs);
    }

    #[test]
    fn nv_driver_descends_within_constraints() {
        let tree = StreamTree::new(13);
        let inst = NewsvendorInstance::generate(&tree, 24, 4, 0.6);
        let mut lmo = NvLmo::new(&inst);
        let x0 = inst.feasible_start();
        let mut backend = NativeNv::new(inst.clone(), 16,
                                        NativeMode::Sequential);
        let (x, trace) = run_nv(&mut backend, &mut lmo, x0, 8, 5,
                                &tree.subtree(&[0])).unwrap();
        assert!(inst.is_feasible(&x, 1e-3));
        assert_eq!(trace.objs.len(), 8);
        assert_eq!(lmo.solves, 8 * 5);
        let first = trace.objs[0];
        let last = *trace.objs.last().unwrap();
        assert!(last <= first * 1.05, "cost should not blow up: {} vs {}",
                last, first);
    }
}
