//! Frank-Wolfe drivers (paper Algorithms 1 and 2), generic over the
//! execution backend.
//!
//! Task 1's epoch (resample + M steps + analytic LMO) is entirely inside the
//! backend — the XLA arm runs it as ONE device dispatch.  Task 2 interleaves
//! backend gradient estimates with the LP LMO on the host, so the driver
//! owns the loop.

use anyhow::Result;

use crate::backend::{MvBackend, MvBatchBackend, NvBackend, NvBatchBackend};
use crate::rng::StreamTree;
use crate::tasks::newsvendor::NvLmo;
use crate::util::profile::{Phase, Profiler};
use crate::util::timer::Timer;

use super::panel::{run_panel_ctl, PanelCtl, PanelHook, PanelOutcome};
use super::progress::{NullSink, ProgressSink, StepEvent};
use super::schedule::fw_gamma;

/// Objective + timing trace of one optimization run.
#[derive(Debug, Clone, Default)]
pub struct FwTrace {
    /// Empirical objective at the end of each epoch.
    pub objs: Vec<f64>,
    /// Wall-clock seconds per epoch.
    pub epoch_s: Vec<f64>,
    /// Per-phase attribution of this replication's wall-clock
    /// (DESIGN.md §15).  Batched runs attribute at the panel level
    /// instead — see [`super::panel::PanelOutcome::profile`].
    pub profile: Profiler,
}

impl FwTrace {
    pub fn total_s(&self) -> f64 {
        self.epoch_s.iter().sum()
    }
}

/// Algorithm 1: `epochs` fused epochs on any [`MvBackend`].
///
/// `tree` must be the *replication-level* stream tree; epoch panels use
/// paths `[epoch]`.  Equivalent to [`run_mv_ctl`] with a null sink.
pub fn run_mv<B: MvBackend + ?Sized>(
    backend: &mut B,
    w0: Vec<f32>,
    epochs: usize,
    tree: &StreamTree,
) -> Result<(Vec<f32>, FwTrace)> {
    run_mv_ctl(backend, w0, epochs, tree, 0, &mut NullSink)
}

/// [`run_mv`] with an observer: `sink` receives one [`StepEvent`] per
/// epoch (after the timed region, so observation never perturbs the
/// recorded timings), tagged as replication `rep`.
pub fn run_mv_ctl<B: MvBackend + ?Sized>(
    backend: &mut B,
    w0: Vec<f32>,
    epochs: usize,
    tree: &StreamTree,
    rep: usize,
    sink: &mut dyn ProgressSink,
) -> Result<(Vec<f32>, FwTrace)> {
    let mut w = w0;
    let mut trace = FwTrace::default();
    for k in 0..epochs {
        let key = tree.jax_key(&[k as u64]);
        let t = Timer::start();
        let (w_next, obj) = backend.epoch(&w, k, key)?;
        let step_s = t.elapsed_s();
        trace.epoch_s.push(step_s);
        trace.objs.push(obj);
        w = w_next;
        // phase attribution outside the timed region: a self-attributing
        // backend's drained split covers the kernel, the residual is
        // dispatch overhead; otherwise the whole wall is compute
        let mut step_prof = Profiler::new();
        match backend.take_profile() {
            Some(p) => {
                step_prof.merge(&p);
                step_prof.add(Phase::Dispatch, step_s - p.sum());
            }
            None => step_prof.add(Phase::Compute, step_s),
        }
        trace.profile.merge(&step_prof);
        sink.on_step(&StepEvent {
            reps: &[rep],
            epoch: k + 1,
            epochs,
            objs: &[obj],
            live: 1,
            step_s,
            profile: step_prof,
        })?;
    }
    Ok((w, trace))
}

/// Algorithm 2: per-iteration gradient (backend) + LP LMO (host) + update,
/// resampling every `m_inner` iterations via the epoch key.  Equivalent
/// to [`run_nv_ctl`] with a null sink.
pub fn run_nv<B: NvBackend + ?Sized>(
    backend: &mut B,
    lmo: &mut NvLmo,
    x0: Vec<f32>,
    epochs: usize,
    m_inner: usize,
    tree: &StreamTree,
) -> Result<(Vec<f32>, FwTrace)> {
    run_nv_ctl(backend, lmo, x0, epochs, m_inner, tree, 0, &mut NullSink)
}

/// [`run_nv`] with an observer: `sink` receives one [`StepEvent`] per
/// epoch (outside the timed region), tagged as replication `rep`.
#[allow(clippy::too_many_arguments)]
pub fn run_nv_ctl<B: NvBackend + ?Sized>(
    backend: &mut B,
    lmo: &mut NvLmo,
    x0: Vec<f32>,
    epochs: usize,
    m_inner: usize,
    tree: &StreamTree,
    rep: usize,
    sink: &mut dyn ProgressSink,
) -> Result<(Vec<f32>, FwTrace)> {
    let mut x = x0;
    let mut trace = FwTrace::default();
    let mut obj = f64::NAN;
    for k in 0..epochs {
        // one key per epoch ⇒ the backend's panel is frozen for m_inner
        // steps (Algorithm 2 line 5), counter-based RNG guarantees identity
        let key = tree.jax_key(&[k as u64]);
        let t = Timer::start();
        // sub-interval walls for phase attribution — raw accumulators
        // only; booking happens after the timed region ends
        let mut lmo_s = 0.0f64;
        let mut upd_s = 0.0f64;
        for m in 0..m_inner {
            let (g, o) = backend.grad_obj(&x, key)?;
            obj = o;
            let t_lmo = Timer::start();
            let s = lmo.solve(&g)?;
            lmo_s += t_lmo.elapsed_s();
            let gamma = fw_gamma(k, m, m_inner);
            let t_upd = Timer::start();
            crate::linalg::vector::fw_update(&mut x, &s, gamma);
            upd_s += t_upd.elapsed_s();
        }
        let step_s = t.elapsed_s();
        trace.epoch_s.push(step_s);
        trace.objs.push(obj);
        let mut step_prof = Profiler::new();
        match backend.take_profile() {
            Some(p) => {
                step_prof.merge(&p);
                step_prof.add(Phase::Dispatch,
                              step_s - p.sum() - lmo_s - upd_s);
            }
            None => step_prof.add(Phase::Compute, step_s - lmo_s - upd_s),
        }
        step_prof.add(Phase::Lmo, lmo_s);
        step_prof.add(Phase::Reduce, upd_s);
        trace.profile.merge(&step_prof);
        sink.on_step(&StepEvent {
            reps: &[rep],
            epoch: k + 1,
            epochs,
            objs: &[obj],
            live: 1,
            step_s,
            profile: step_prof,
        })?;
    }
    Ok((x, trace))
}

// ---------------------------------------------------------------------------
// Replication-batched drivers: PanelHooks over the generic loop
// (DESIGN.md §11/§12).  Shard-agnostic by construction: a sharded plane
// (`backend::plane::ShardedBatch`, DESIGN.md §13) implements the same
// `*BatchBackend` traits, so these drivers never see shard boundaries.
// ---------------------------------------------------------------------------

/// Epoch-task hook (Algorithm 1, and the mean-CVaR task riding the same
/// contract): one `epoch_batch` call per outer step.
struct EpochHook<'a, B: ?Sized> {
    backend: &'a mut B,
    keys: Vec<[u32; 2]>,
}

impl<B: MvBatchBackend + ?Sized> PanelHook for EpochHook<'_, B> {
    fn prepare(&mut self, k: usize, trees: &[StreamTree]) -> Result<()> {
        // key derivation stays outside the timed region, as in run_mv
        self.keys.clear();
        self.keys.extend(trees.iter().map(|t| t.jax_key(&[k as u64])));
        Ok(())
    }

    fn advance(&mut self, k: usize, panel: &mut [f32],
               _trees: &[StreamTree], vals: &mut [f64]) -> Result<()> {
        self.backend.epoch_batch(panel, k, &self.keys, vals)
    }

    fn collect_profile(&mut self, step_s: f64, prof: &mut Profiler) {
        match self.backend.take_profile() {
            Some(p) => {
                prof.merge(&p);
                prof.add(Phase::Dispatch, step_s - p.sum());
            }
            None => prof.add(Phase::Compute, step_s),
        }
    }
}

/// Algorithm 1 over all replications at once: one `epoch_batch` call per
/// epoch.  `trees[r]` must be replication r's stream subtree — the SAME
/// subtree [`run_mv`] receives — so batched and sequential runs draw
/// identical panels and produce bit-identical iterates.  Equivalent to
/// [`run_mv_batch_ctl`] with a null sink and no budget.
pub fn run_mv_batch<B: MvBatchBackend + ?Sized>(
    backend: &mut B,
    w0: &[f32],
    epochs: usize,
    trees: &[StreamTree],
) -> Result<(Vec<f32>, Vec<FwTrace>)> {
    let mut sink = NullSink;
    let mut ctl = PanelCtl { sink: &mut sink, budget: None };
    let out = run_mv_batch_ctl(backend, w0, epochs, trees, &mut ctl)?;
    Ok((out.panel, out.traces))
}

/// [`run_mv_batch`] under a [`PanelCtl`]: per-step progress events plus
/// the opt-in adaptive replication budget (DESIGN.md §14).
pub fn run_mv_batch_ctl<B: MvBatchBackend + ?Sized>(
    backend: &mut B,
    w0: &[f32],
    epochs: usize,
    trees: &[StreamTree],
    ctl: &mut PanelCtl<'_>,
) -> Result<PanelOutcome> {
    let r = trees.len();
    anyhow::ensure!(backend.batch_reps() == r,
                    "backend built for {} replications, got {} trees",
                    backend.batch_reps(), r);
    let mut hook = EpochHook { backend, keys: Vec::with_capacity(r) };
    run_panel_ctl(&mut hook, w0, epochs, trees, ctl)
}

/// Algorithm-2 hook: one outer step = M inner iterations, each ONE batched
/// gradient call plus ONE panel LMO solve over all R replications
/// (`NvLmo::solve_panel_into`, DESIGN.md §17) — the LP wall fans out over
/// `threads` pool workers instead of looping rows on the driver thread.
struct NvStepHook<'a, B: ?Sized> {
    backend: &'a mut B,
    lmos: &'a mut [NvLmo],
    m_inner: usize,
    d: usize,
    threads: usize,
    g: Vec<f32>,
    /// R×d vertex panel for the batched LMO solves, reused across every
    /// step of the run (DESIGN.md §16) and carved into disjoint per-row
    /// `&mut` chunks by the pool fan-out.
    verts: Vec<f32>,
    /// Shared-constraint seed for the panel LMO: phase-1 tableau of the
    /// one `(A, cap)` system all rows share, built once and warm-reused
    /// across steps (`lp::PanelWorkspace`).
    seed: crate::lp::PanelWorkspace,
    keys: Vec<[u32; 2]>,
    /// Panel-LMO wall accumulated during the current step (drained by
    /// `collect_profile` into `Phase::Lmo`).
    lmo_s: f64,
    /// Host FW-update wall for the current step (drained into
    /// `Phase::Reduce`, matching `run_nv`'s `upd_s` attribution).
    upd_s: f64,
}

impl<B: NvBatchBackend + ?Sized> PanelHook for NvStepHook<'_, B> {
    fn prepare(&mut self, k: usize, trees: &[StreamTree]) -> Result<()> {
        // key derivation stays outside the timed region, as in run_nv
        self.keys.clear();
        self.keys.extend(trees.iter().map(|t| t.jax_key(&[k as u64])));
        Ok(())
    }

    fn advance(&mut self, k: usize, panel: &mut [f32],
               _trees: &[StreamTree], vals: &mut [f64]) -> Result<()> {
        let d = self.d;
        for m in 0..self.m_inner {
            // each inner iteration overwrites vals; the step records the
            // LAST inner objective, exactly as run_nv's sequential loop
            self.backend.grad_obj_batch(panel, &self.keys, &mut self.g,
                                        vals)?;
            let gamma = fw_gamma(k, m, self.m_inner);
            // all R LPs advance as one panel: shared-seed phase 2 per
            // row, rows fanned out over the worker pool
            let t_lmo = Timer::start();
            NvLmo::solve_panel_into(self.lmos, &mut self.seed, &self.g,
                                    &mut self.verts, self.threads)?;
            self.lmo_s += t_lmo.elapsed_s();
            let t_upd = Timer::start();
            for (xi, vi) in panel.chunks_mut(d)
                .zip(self.verts.chunks(d)) {
                crate::linalg::vector::fw_update(xi, vi, gamma);
            }
            self.upd_s += t_upd.elapsed_s();
        }
        Ok(())
    }

    fn collect_profile(&mut self, step_s: f64, prof: &mut Profiler) {
        // panel-core LP time books as lmo; the host fw_update loop books
        // as reduce — the same split run_nv applies to its sequential
        // lmo_s / upd_s sub-intervals, so batch and sequential profiles
        // stay comparable phase-by-phase
        let lmo_s = std::mem::take(&mut self.lmo_s);
        let upd_s = std::mem::take(&mut self.upd_s);
        match self.backend.take_profile() {
            Some(p) => {
                prof.merge(&p);
                prof.add(Phase::Dispatch, step_s - p.sum() - lmo_s - upd_s);
            }
            None => prof.add(Phase::Compute, step_s - lmo_s - upd_s),
        }
        prof.add(Phase::Lmo, lmo_s);
        prof.add(Phase::Reduce, upd_s);
    }
}

/// Algorithm 2 over all replications at once.  `threads` sizes the pool
/// fan-out of the panel LMO (1 = inline on the driver thread).  Equivalent
/// to [`run_nv_batch_ctl`] with a null sink and no budget.
#[allow(clippy::too_many_arguments)]
pub fn run_nv_batch<B: NvBatchBackend + ?Sized>(
    backend: &mut B,
    lmos: &mut [NvLmo],
    x0: &[f32],
    epochs: usize,
    m_inner: usize,
    trees: &[StreamTree],
    threads: usize,
) -> Result<(Vec<f32>, Vec<FwTrace>)> {
    let mut sink = NullSink;
    let mut ctl = PanelCtl { sink: &mut sink, budget: None };
    let out =
        run_nv_batch_ctl(backend, lmos, x0, epochs, m_inner, trees, threads,
                         &mut ctl)?;
    Ok((out.panel, out.traces))
}

/// [`run_nv_batch`] under a [`PanelCtl`]: per-step progress events plus
/// the opt-in adaptive replication budget (DESIGN.md §14).
#[allow(clippy::too_many_arguments)]
pub fn run_nv_batch_ctl<B: NvBatchBackend + ?Sized>(
    backend: &mut B,
    lmos: &mut [NvLmo],
    x0: &[f32],
    epochs: usize,
    m_inner: usize,
    trees: &[StreamTree],
    threads: usize,
    ctl: &mut PanelCtl<'_>,
) -> Result<PanelOutcome> {
    let r = trees.len();
    let d = x0.len();
    anyhow::ensure!(backend.batch_reps() == r,
                    "backend built for {} replications, got {} trees",
                    backend.batch_reps(), r);
    anyhow::ensure!(lmos.len() == r, "need one LMO per replication");
    let mut hook = NvStepHook {
        backend,
        lmos,
        m_inner,
        d,
        threads: threads.max(1),
        g: vec![0.0f32; r * d],
        verts: vec![0.0f32; r * d],
        seed: crate::lp::PanelWorkspace::new(),
        keys: Vec::with_capacity(r),
        lmo_s: 0.0,
        upd_s: 0.0,
    };
    run_panel_ctl(&mut hook, x0, epochs, trees, ctl)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::{NativeMv, NativeNv, NativeMode};
    use crate::sim::{AssetUniverse, NewsvendorInstance};
    use crate::tasks::mean_variance::in_simplex;

    #[test]
    fn mv_driver_descends_and_stays_feasible() {
        let tree = StreamTree::new(11);
        let u = AssetUniverse::generate(&tree, 48);
        let mut backend = NativeMv::new(u.clone(), 32, 10,
                                        NativeMode::Sequential);
        let w0 = vec![1.0 / 48.0; 48];
        let (w, trace) = run_mv(&mut backend, w0.clone(), 12,
                                &tree.subtree(&[0])).unwrap();
        assert_eq!(trace.objs.len(), 12);
        assert_eq!(trace.epoch_s.len(), 12);
        assert!(in_simplex(&w, 1e-4));
        // the tail of the trace must improve on the start (each epoch's
        // objective is estimated on a fresh panel, so allow MC noise)
        let first = trace.objs[0];
        let last = *trace.objs.last().unwrap();
        assert!(last <= first + 0.02 * first.abs(), "{} !<= {}", last, first);
        // and beat the uniform portfolio's exact objective
        assert!(u.exact_objective(&w) < u.exact_objective(&w0));
    }

    #[test]
    fn mv_driver_reproducible() {
        let tree = StreamTree::new(12);
        let u = AssetUniverse::generate(&tree, 16);
        let w0 = vec![1.0 / 16.0; 16];
        let run = |_i| {
            let mut b = NativeMv::new(u.clone(), 8, 5, NativeMode::Sequential);
            run_mv(&mut b, w0.clone(), 5, &tree.subtree(&[3])).unwrap()
        };
        let (w1, t1) = run(0);
        let (w2, t2) = run(1);
        assert_eq!(w1, w2);
        assert_eq!(t1.objs, t2.objs);
    }

    #[test]
    fn nv_driver_descends_within_constraints() {
        let tree = StreamTree::new(13);
        let inst = NewsvendorInstance::generate(&tree, 24, 4, 0.6);
        let mut lmo = NvLmo::new(&inst);
        let x0 = inst.feasible_start();
        let mut backend = NativeNv::new(inst.clone(), 16,
                                        NativeMode::Sequential);
        let (x, trace) = run_nv(&mut backend, &mut lmo, x0, 8, 5,
                                &tree.subtree(&[0])).unwrap();
        assert!(inst.is_feasible(&x, 1e-3));
        assert_eq!(trace.objs.len(), 8);
        assert_eq!(lmo.solves, 8 * 5);
        let first = trace.objs[0];
        let last = *trace.objs.last().unwrap();
        assert!(last <= first * 1.05, "cost should not blow up: {} vs {}",
                last, first);
    }

    #[test]
    fn mv_batch_driver_matches_sequential_driver_bitwise() {
        use crate::backend::native::NativeMvBatch;
        let (d, reps, epochs) = (12usize, 4usize, 5usize);
        let root = StreamTree::new(91);
        let u = AssetUniverse::generate(&root, d);
        let w0 = vec![1.0f32 / d as f32; d];
        let trees: Vec<StreamTree> =
            (0..reps).map(|r| root.subtree(&[1000 + r as u64])).collect();

        let mut batch = NativeMvBatch::new(&u, 8, 3, reps, 3);
        let (w_panel, traces) =
            run_mv_batch(&mut batch, &w0, epochs, &trees).unwrap();

        for (r, tree) in trees.iter().enumerate() {
            let mut single =
                NativeMv::new(u.clone(), 8, 3, NativeMode::Sequential);
            let (w_seq, t_seq) =
                run_mv(&mut single, w0.clone(), epochs, tree).unwrap();
            assert_eq!(&w_panel[r * d..(r + 1) * d], w_seq.as_slice(),
                       "rep {}", r);
            assert_eq!(traces[r].objs, t_seq.objs, "rep {}", r);
        }
    }

    #[test]
    fn cvar_batch_driver_matches_sequential_driver_bitwise() {
        // The mean-CVaR task rides run_mv/run_mv_batch through the epoch
        // contract — same bitwise guarantee, joint [w, t] rows.
        use crate::backend::native::{NativeCvar, NativeCvarBatch};
        use crate::tasks::cvar;
        let (d, reps, epochs) = (9usize, 3usize, 4usize);
        let root = StreamTree::new(93);
        let u = AssetUniverse::generate(&root, d);
        let x0 = cvar::start_iterate(d);
        let row = d + 1;
        let trees: Vec<StreamTree> =
            (0..reps).map(|r| root.subtree(&[1000 + r as u64])).collect();

        let mut batch = NativeCvarBatch::new(&u, 8, 3, reps, 2);
        let (x_panel, traces) =
            run_mv_batch(&mut batch, &x0, epochs, &trees).unwrap();

        for (r, tree) in trees.iter().enumerate() {
            let mut single =
                NativeCvar::new(u.clone(), 8, 3, NativeMode::Sequential);
            let (x_seq, t_seq) =
                run_mv(&mut single, x0.clone(), epochs, tree).unwrap();
            assert_eq!(&x_panel[r * row..(r + 1) * row], x_seq.as_slice(),
                       "rep {}", r);
            assert_eq!(traces[r].objs, t_seq.objs, "rep {}", r);
            assert!(cvar::in_product(&x_panel[r * row..(r + 1) * row],
                                     1e-5));
        }
    }

    #[test]
    fn nv_batch_driver_matches_sequential_driver_bitwise() {
        use crate::backend::native::NativeNvBatch;
        let (d, reps, epochs, m_inner) = (10usize, 3usize, 4usize, 3usize);
        let root = StreamTree::new(92);
        let inst = NewsvendorInstance::generate(&root, d, 2, 0.6);
        let x0 = inst.feasible_start();
        let trees: Vec<StreamTree> =
            (0..reps).map(|r| root.subtree(&[1000 + r as u64])).collect();

        let mut batch = NativeNvBatch::new(&inst, 8, reps, 2);
        let mut lmos: Vec<NvLmo> =
            (0..reps).map(|_| NvLmo::new(&inst)).collect();
        let (x_panel, traces) =
            run_nv_batch(&mut batch, &mut lmos, &x0, epochs, m_inner, &trees,
                         2)
                .unwrap();

        for (r, tree) in trees.iter().enumerate() {
            let mut single =
                NativeNv::new(inst.clone(), 8, NativeMode::Sequential);
            let mut lmo = NvLmo::new(&inst);
            let (x_seq, t_seq) = run_nv(&mut single, &mut lmo, x0.clone(),
                                        epochs, m_inner, tree).unwrap();
            assert_eq!(&x_panel[r * d..(r + 1) * d], x_seq.as_slice(),
                       "rep {}", r);
            assert_eq!(traces[r].objs, t_seq.objs, "rep {}", r);
        }
    }
}
