//! Frank-Wolfe drivers (paper Algorithms 1 and 2), generic over the
//! execution backend.
//!
//! Task 1's epoch (resample + M steps + analytic LMO) is entirely inside the
//! backend — the XLA arm runs it as ONE device dispatch.  Task 2 interleaves
//! backend gradient estimates with the LP LMO on the host, so the driver
//! owns the loop.

use anyhow::Result;

use crate::backend::{MvBackend, MvBatchBackend, NvBackend, NvBatchBackend};
use crate::rng::StreamTree;
use crate::tasks::newsvendor::NvLmo;
use crate::util::timer::Timer;

use super::schedule::fw_gamma;

/// Objective + timing trace of one optimization run.
#[derive(Debug, Clone, Default)]
pub struct FwTrace {
    /// Empirical objective at the end of each epoch.
    pub objs: Vec<f64>,
    /// Wall-clock seconds per epoch.
    pub epoch_s: Vec<f64>,
}

impl FwTrace {
    pub fn total_s(&self) -> f64 {
        self.epoch_s.iter().sum()
    }
}

/// Algorithm 1: `epochs` fused epochs on any [`MvBackend`].
///
/// `tree` must be the *replication-level* stream tree; epoch panels use
/// paths `[epoch]`.
pub fn run_mv<B: MvBackend + ?Sized>(
    backend: &mut B,
    w0: Vec<f32>,
    epochs: usize,
    tree: &StreamTree,
) -> Result<(Vec<f32>, FwTrace)> {
    let mut w = w0;
    let mut trace = FwTrace::default();
    for k in 0..epochs {
        let key = tree.jax_key(&[k as u64]);
        let t = Timer::start();
        let (w_next, obj) = backend.epoch(&w, k, key)?;
        trace.epoch_s.push(t.elapsed_s());
        trace.objs.push(obj);
        w = w_next;
    }
    Ok((w, trace))
}

/// Algorithm 2: per-iteration gradient (backend) + LP LMO (host) + update,
/// resampling every `m_inner` iterations via the epoch key.
pub fn run_nv<B: NvBackend + ?Sized>(
    backend: &mut B,
    lmo: &mut NvLmo,
    x0: Vec<f32>,
    epochs: usize,
    m_inner: usize,
    tree: &StreamTree,
) -> Result<(Vec<f32>, FwTrace)> {
    let mut x = x0;
    let mut trace = FwTrace::default();
    let mut obj = f64::NAN;
    for k in 0..epochs {
        // one key per epoch ⇒ the backend's panel is frozen for m_inner
        // steps (Algorithm 2 line 5), counter-based RNG guarantees identity
        let key = tree.jax_key(&[k as u64]);
        let t = Timer::start();
        for m in 0..m_inner {
            let (g, o) = backend.grad_obj(&x, key)?;
            obj = o;
            let s = lmo.solve(&g)?;
            let gamma = fw_gamma(k, m, m_inner);
            crate::linalg::vector::fw_update(&mut x, &s, gamma);
        }
        trace.epoch_s.push(t.elapsed_s());
        trace.objs.push(obj);
    }
    Ok((x, trace))
}

// ---------------------------------------------------------------------------
// Replication-batched drivers (DESIGN.md §11)
// ---------------------------------------------------------------------------

/// Distribute one batched-call wall-clock across the per-replication traces
/// (total batched time == sum over replications stays comparable with the
/// sequential protocol's per-replication totals).
fn push_epoch(traces: &mut [FwTrace], objs: &[f64], batch_s: f64) {
    let share = batch_s / traces.len().max(1) as f64;
    for (trace, &obj) in traces.iter_mut().zip(objs) {
        trace.epoch_s.push(share);
        trace.objs.push(obj);
    }
}

/// Algorithm 1 over all replications at once: one `epoch_batch` call per
/// epoch.  `trees[r]` must be replication r's stream subtree — the SAME
/// subtree [`run_mv`] receives — so batched and sequential runs draw
/// identical panels and produce bit-identical iterates.
pub fn run_mv_batch<B: MvBatchBackend + ?Sized>(
    backend: &mut B,
    w0: &[f32],
    epochs: usize,
    trees: &[StreamTree],
) -> Result<(Vec<f32>, Vec<FwTrace>)> {
    let r = trees.len();
    anyhow::ensure!(backend.batch_reps() == r,
                    "backend built for {} replications, got {} trees",
                    backend.batch_reps(), r);
    let mut w = Vec::with_capacity(r * w0.len());
    for _ in 0..r {
        w.extend_from_slice(w0);
    }
    let mut traces = vec![FwTrace::default(); r];
    let mut keys = vec![[0u32; 2]; r];
    for k in 0..epochs {
        for (key, tree) in keys.iter_mut().zip(trees) {
            *key = tree.jax_key(&[k as u64]);
        }
        let t = Timer::start();
        let objs = backend.epoch_batch(&mut w, k, &keys)?;
        push_epoch(&mut traces, &objs, t.elapsed_s());
    }
    Ok((w, traces))
}

/// Algorithm 2 over all replications at once: each inner iteration costs
/// ONE batched gradient call plus R host-side LP LMO solves (the LMO is
/// host-side in the sequential path too).
pub fn run_nv_batch<B: NvBatchBackend + ?Sized>(
    backend: &mut B,
    lmos: &mut [NvLmo],
    x0: &[f32],
    epochs: usize,
    m_inner: usize,
    trees: &[StreamTree],
) -> Result<(Vec<f32>, Vec<FwTrace>)> {
    let r = trees.len();
    let d = x0.len();
    anyhow::ensure!(backend.batch_reps() == r,
                    "backend built for {} replications, got {} trees",
                    backend.batch_reps(), r);
    anyhow::ensure!(lmos.len() == r, "need one LMO per replication");
    let mut x = Vec::with_capacity(r * d);
    for _ in 0..r {
        x.extend_from_slice(x0);
    }
    let mut g = vec![0.0f32; r * d];
    let mut traces = vec![FwTrace::default(); r];
    let mut keys = vec![[0u32; 2]; r];
    let mut objs = vec![f64::NAN; r];
    for k in 0..epochs {
        for (key, tree) in keys.iter_mut().zip(trees) {
            *key = tree.jax_key(&[k as u64]);
        }
        let t = Timer::start();
        for m in 0..m_inner {
            objs = backend.grad_obj_batch(&x, &keys, &mut g)?;
            let gamma = fw_gamma(k, m, m_inner);
            for (i, lmo) in lmos.iter_mut().enumerate() {
                let s = lmo.solve(&g[i * d..(i + 1) * d])?;
                crate::linalg::vector::fw_update(
                    &mut x[i * d..(i + 1) * d], &s, gamma);
            }
        }
        push_epoch(&mut traces, &objs, t.elapsed_s());
    }
    Ok((x, traces))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::{NativeMv, NativeNv, NativeMode};
    use crate::sim::{AssetUniverse, NewsvendorInstance};
    use crate::tasks::mean_variance::in_simplex;

    #[test]
    fn mv_driver_descends_and_stays_feasible() {
        let tree = StreamTree::new(11);
        let u = AssetUniverse::generate(&tree, 48);
        let mut backend = NativeMv::new(u.clone(), 32, 10,
                                        NativeMode::Sequential);
        let w0 = vec![1.0 / 48.0; 48];
        let (w, trace) = run_mv(&mut backend, w0.clone(), 12,
                                &tree.subtree(&[0])).unwrap();
        assert_eq!(trace.objs.len(), 12);
        assert_eq!(trace.epoch_s.len(), 12);
        assert!(in_simplex(&w, 1e-4));
        // the tail of the trace must improve on the start (each epoch's
        // objective is estimated on a fresh panel, so allow MC noise)
        let first = trace.objs[0];
        let last = *trace.objs.last().unwrap();
        assert!(last <= first + 0.02 * first.abs(), "{} !<= {}", last, first);
        // and beat the uniform portfolio's exact objective
        assert!(u.exact_objective(&w) < u.exact_objective(&w0));
    }

    #[test]
    fn mv_driver_reproducible() {
        let tree = StreamTree::new(12);
        let u = AssetUniverse::generate(&tree, 16);
        let w0 = vec![1.0 / 16.0; 16];
        let run = |_i| {
            let mut b = NativeMv::new(u.clone(), 8, 5, NativeMode::Sequential);
            run_mv(&mut b, w0.clone(), 5, &tree.subtree(&[3])).unwrap()
        };
        let (w1, t1) = run(0);
        let (w2, t2) = run(1);
        assert_eq!(w1, w2);
        assert_eq!(t1.objs, t2.objs);
    }

    #[test]
    fn nv_driver_descends_within_constraints() {
        let tree = StreamTree::new(13);
        let inst = NewsvendorInstance::generate(&tree, 24, 4, 0.6);
        let mut lmo = NvLmo::new(&inst);
        let x0 = inst.feasible_start();
        let mut backend = NativeNv::new(inst.clone(), 16,
                                        NativeMode::Sequential);
        let (x, trace) = run_nv(&mut backend, &mut lmo, x0, 8, 5,
                                &tree.subtree(&[0])).unwrap();
        assert!(inst.is_feasible(&x, 1e-3));
        assert_eq!(trace.objs.len(), 8);
        assert_eq!(lmo.solves, 8 * 5);
        let first = trace.objs[0];
        let last = *trace.objs.last().unwrap();
        assert!(last <= first * 1.05, "cost should not blow up: {} vs {}",
                last, first);
    }

    #[test]
    fn mv_batch_driver_matches_sequential_driver_bitwise() {
        use crate::backend::native::NativeMvBatch;
        let (d, reps, epochs) = (12usize, 4usize, 5usize);
        let root = StreamTree::new(91);
        let u = AssetUniverse::generate(&root, d);
        let w0 = vec![1.0f32 / d as f32; d];
        let trees: Vec<StreamTree> =
            (0..reps).map(|r| root.subtree(&[1000 + r as u64])).collect();

        let mut batch = NativeMvBatch::new(&u, 8, 3, reps, 3);
        let (w_panel, traces) =
            run_mv_batch(&mut batch, &w0, epochs, &trees).unwrap();

        for (r, tree) in trees.iter().enumerate() {
            let mut single =
                NativeMv::new(u.clone(), 8, 3, NativeMode::Sequential);
            let (w_seq, t_seq) =
                run_mv(&mut single, w0.clone(), epochs, tree).unwrap();
            assert_eq!(&w_panel[r * d..(r + 1) * d], w_seq.as_slice(),
                       "rep {}", r);
            assert_eq!(traces[r].objs, t_seq.objs, "rep {}", r);
        }
    }

    #[test]
    fn nv_batch_driver_matches_sequential_driver_bitwise() {
        use crate::backend::native::NativeNvBatch;
        let (d, reps, epochs, m_inner) = (10usize, 3usize, 4usize, 3usize);
        let root = StreamTree::new(92);
        let inst = NewsvendorInstance::generate(&root, d, 2, 0.6);
        let x0 = inst.feasible_start();
        let trees: Vec<StreamTree> =
            (0..reps).map(|r| root.subtree(&[1000 + r as u64])).collect();

        let mut batch = NativeNvBatch::new(&inst, 8, reps, 2);
        let mut lmos: Vec<NvLmo> =
            (0..reps).map(|_| NvLmo::new(&inst)).collect();
        let (x_panel, traces) =
            run_nv_batch(&mut batch, &mut lmos, &x0, epochs, m_inner, &trees)
                .unwrap();

        for (r, tree) in trees.iter().enumerate() {
            let mut single =
                NativeNv::new(inst.clone(), 8, NativeMode::Sequential);
            let mut lmo = NvLmo::new(&inst);
            let (x_seq, t_seq) = run_nv(&mut single, &mut lmo, x0.clone(),
                                        epochs, m_inner, tree).unwrap();
            assert_eq!(&x_panel[r * d..(r + 1) * d], x_seq.as_slice(),
                       "rep {}", r);
            assert_eq!(traces[r].objs, t_seq.objs, "rep {}", r);
        }
    }
}
