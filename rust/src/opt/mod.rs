//! The paper's optimization algorithms, backend-generic.
//!
//! * [`frank_wolfe`] — Algorithms 1 (simplex LMO, fused epochs) and 2
//!   (LP LMO, per-iteration gradients);
//! * [`sqn`] — Algorithm 3 (stochastic quasi-Newton) with Algorithm 4
//!   Hessian updating delegated to the backend;
//! * [`schedule`] — the step-size rules.

pub mod frank_wolfe;
pub mod schedule;
pub mod sqn;

pub use frank_wolfe::{run_mv, run_nv, FwTrace};
pub use sqn::{run_sqn, SqnConfig, SqnTrace};
