//! The paper's optimization algorithms, backend-generic.
//!
//! * [`frank_wolfe`] — Algorithms 1 (simplex LMO, fused epochs) and 2
//!   (LP LMO, per-iteration gradients);
//! * [`sqn`] — Algorithm 3 (stochastic quasi-Newton) with Algorithm 4
//!   Hessian updating delegated to the backend;
//! * [`schedule`] — the step-size rules.
//!
//! Every driver has a replication-batched variant (`run_*_batch`) that
//! advances all R replications of an experiment through the corresponding
//! `*BatchBackend` in one call per step — bit-identical per replication to
//! the sequential driver under the same stream subtrees (DESIGN.md §11).
//! All batched variants are task-specific [`panel::PanelHook`]s driven by
//! the ONE generic replication-panel loop in [`panel`] (DESIGN.md §12).

pub mod frank_wolfe;
pub mod panel;
pub mod schedule;
pub mod sqn;

pub use frank_wolfe::{run_mv, run_mv_batch, run_nv, run_nv_batch, FwTrace};
pub use panel::{run_panel, PanelHook};
pub use sqn::{run_sqn, run_sqn_batch, SqnConfig, SqnTrace};
