//! The paper's optimization algorithms, backend-generic.
//!
//! * [`frank_wolfe`] — Algorithms 1 (simplex LMO, fused epochs) and 2
//!   (LP LMO, per-iteration gradients);
//! * [`sqn`] — Algorithm 3 (stochastic quasi-Newton) with Algorithm 4
//!   Hessian updating delegated to the backend;
//! * [`schedule`] — the step-size rules.
//!
//! Every driver has a replication-batched variant (`run_*_batch`) that
//! advances all R replications of an experiment through the corresponding
//! `*BatchBackend` in one call per step — bit-identical per replication to
//! the sequential driver under the same stream subtrees (DESIGN.md §11).
//! All batched variants are task-specific [`panel::PanelHook`]s driven by
//! the ONE generic replication-panel loop in [`panel`] (DESIGN.md §12).
//!
//! Every driver also has a controlled variant (`run_*_ctl`) that reports
//! each outer step to a [`progress::ProgressSink`] — the execution
//! plane's observer hook (DESIGN.md §14) — and, for the batched drivers,
//! applies the opt-in [`crate::config::BudgetPolicy`] through
//! [`panel::run_panel_ctl`].  The plain names are thin wrappers over the
//! controlled ones with a null sink and no budget.

pub mod frank_wolfe;
pub mod panel;
pub mod progress;
pub mod schedule;
pub mod sqn;

pub use frank_wolfe::{run_mv, run_mv_batch, run_mv_batch_ctl, run_mv_ctl,
                      run_nv, run_nv_batch, run_nv_batch_ctl, run_nv_ctl,
                      FwTrace};
pub use panel::{run_panel, run_panel_ctl, PanelCtl, PanelHook,
                PanelOutcome};
pub use progress::{NullSink, ProgressSink, SharedSink, StepEvent,
                   TracingSink};
pub use sqn::{run_sqn, run_sqn_batch, run_sqn_batch_ctl, run_sqn_ctl,
              SqnBatchOutcome, SqnConfig, SqnTrace};
