//! Step-size schedules (paper Algorithms 1-3).

/// Frank-Wolfe step γ = 2/(kM + m + 2) (Algorithm 1 line 9 / Algorithm 2
/// line 9): `k` is the epoch, `m` the inner iteration, `m_inner` = M.
#[inline]
pub fn fw_gamma(k_epoch: usize, m: usize, m_inner: usize) -> f32 {
    2.0 / (k_epoch as f32 * m_inner as f32 + m as f32 + 2.0)
}

/// SQN step α_k = β/k (Algorithm 3 line 7, 1-indexed k).
#[inline]
pub fn sqn_alpha(beta: f32, k: usize) -> f32 {
    debug_assert!(k >= 1);
    beta / k as f32
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gamma_matches_formula_and_decays() {
        assert_eq!(fw_gamma(0, 0, 25), 1.0); // 2/(0+0+2)
        assert!((fw_gamma(1, 0, 25) - 2.0 / 27.0).abs() < 1e-7);
        assert!((fw_gamma(2, 3, 25) - 2.0 / 55.0).abs() < 1e-7);
        // strictly decreasing along the flattened iteration index
        let mut last = f32::INFINITY;
        for k in 0..4 {
            for m in 0..25 {
                let g = fw_gamma(k, m, 25);
                assert!(g < last);
                assert!(g > 0.0 && g <= 1.0);
                last = g;
            }
        }
    }

    #[test]
    fn gamma_continuous_across_epoch_boundary() {
        // last step of epoch k and first of epoch k+1 are adjacent in the
        // global schedule
        let end = fw_gamma(0, 24, 25); // 2/(24+2)
        let next = fw_gamma(1, 0, 25); // 2/(25+2)
        assert!(next < end);
        assert!((1.0 / next - 1.0 / end - 0.5).abs() < 1e-6);
    }

    #[test]
    fn alpha_is_beta_over_k() {
        assert_eq!(sqn_alpha(2.0, 1), 2.0);
        assert_eq!(sqn_alpha(2.0, 4), 0.5);
        assert!(sqn_alpha(2.0, 100) < sqn_alpha(2.0, 99));
    }
}
