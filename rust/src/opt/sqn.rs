//! Stochastic quasi-Newton driver (paper Algorithm 3, Byrd et al. 2016),
//! generic over [`LrBackend`].
//!
//! The driver owns everything execution-model independent: minibatch index
//! sampling (shared between arms for CRN), the ω̄ averaging, the correction
//! memory, and the gradient/Hessian batch gathering.  The backend supplies
//! the three compute kernels (grad, hvp, H·g).

use anyhow::Result;

use crate::backend::{LrBackend, LrBatchBackend};
use crate::rng::{SampleScratch, StreamTree};
use crate::sim::ClassifyData;
use crate::tasks::{BatchCorrectionMemory, CorrectionMemory};
use crate::util::profile::{Phase, Profiler};
use crate::util::timer::Timer;

use super::panel::{run_panel_ctl, PanelCtl, PanelHook};
use super::progress::{NullSink, ProgressSink, StepEvent};
use super::schedule::sqn_alpha;

#[derive(Debug, Clone)]
pub struct SqnConfig {
    /// Total iterations K.
    pub iters: usize,
    /// Minibatch size b.
    pub batch: usize,
    /// Hessian batch size b_H.
    pub hbatch: usize,
    /// Correction-pair spacing L.
    pub l_every: usize,
    /// Memory size M.
    pub memory: usize,
    /// Step scale β (α_k = β/k).
    pub beta: f32,
    /// Evaluate the tracked loss every this many iterations (0 = never).
    pub track_every: usize,
    /// Rows of the fixed evaluation subset used for the tracked loss.
    pub track_rows: usize,
}

impl SqnConfig {
    pub fn paper_defaults(iters: usize) -> Self {
        SqnConfig {
            iters,
            batch: 50,
            hbatch: 300,
            l_every: 10,
            memory: 25,
            beta: 2.0,
            track_every: 10,
            track_rows: 2048,
        }
    }
}

#[derive(Debug, Clone, Default)]
pub struct SqnTrace {
    /// (iteration, tracked full-subset loss) checkpoints.
    pub checkpoints: Vec<(usize, f64)>,
    /// Minibatch loss per iteration (noisy diagnostic).
    pub batch_loss: Vec<f64>,
    /// Wall-clock seconds per iteration (compute only, tracking excluded).
    pub iter_s: Vec<f64>,
    /// Number of correction pairs accepted.
    pub pairs_accepted: usize,
    /// Number of pairs rejected for curvature.
    pub pairs_rejected: usize,
    /// Per-phase attribution of this replication's wall-clock
    /// (DESIGN.md §15).  Batched runs attribute at the panel level.
    pub profile: Profiler,
}

impl SqnTrace {
    pub fn total_s(&self) -> f64 {
        self.iter_s.iter().sum()
    }

    /// Checkpoint losses as a plain trace (for RSE computation).
    pub fn tracked_losses(&self) -> Vec<f64> {
        self.checkpoints.iter().map(|&(_, l)| l).collect()
    }
}

/// Run Algorithm 3.  `tree` is the replication-level stream; minibatch
/// draws use paths `[1, k]`, Hessian batches `[2, t]`.  Equivalent to
/// [`run_sqn_ctl`] with a null sink.
pub fn run_sqn<B: LrBackend + ?Sized>(
    backend: &mut B,
    data: &ClassifyData,
    cfg: &SqnConfig,
    tree: &StreamTree,
) -> Result<(Vec<f32>, SqnTrace)> {
    run_sqn_ctl(backend, data, cfg, tree, 0, &mut NullSink)
}

/// [`run_sqn`] with an observer: `sink` receives one [`StepEvent`] per
/// iteration (minibatch loss, outside the timed region), tagged as
/// replication `rep`.
pub fn run_sqn_ctl<B: LrBackend + ?Sized>(
    backend: &mut B,
    data: &ClassifyData,
    cfg: &SqnConfig,
    tree: &StreamTree,
    rep: usize,
    sink: &mut dyn ProgressSink,
) -> Result<(Vec<f32>, SqnTrace)> {
    let n = data.n_features;
    let mut w = vec![0.0f32; n];
    let mut trace = SqnTrace::default();
    let mut mem = CorrectionMemory::new(cfg.memory, n);

    // ω̄ accumulators (Algorithm 3 lines 3, 7, 15)
    let mut wbar_acc = vec![0.0f32; n];
    let mut wbar_prev: Option<Vec<f32>> = None;
    let mut t_count: i64 = -1;

    // Fixed evaluation subset for the tracked loss (identical across arms).
    let eval_rows: Vec<usize> = {
        let mut rng = tree.stream(&[0xE7A1]);
        let rows = cfg.track_rows.min(data.n_samples);
        rng.sample_indices(data.n_samples, rows)
    };
    let mut xe: Vec<f32> = Vec::new();
    let mut ze: Vec<f32> = Vec::new();
    data.gather(&eval_rows, &mut xe, &mut ze);

    for k in 1..=cfg.iters {
        let timer = Timer::start();
        // -- Algorithm 3 line 5: choose the minibatch S ---------------------
        // (indices only — each backend owns its gather path: host rows for
        // native, in-graph take() against the resident dataset for XLA)
        let t_idx = Timer::start();
        let mut rng = tree.stream(&[1, k as u64]);
        let idx = rng.sample_indices(data.n_samples, cfg.batch.min(data.n_samples));
        let dispatch_s = t_idx.elapsed_s();

        // -- line 6: stochastic gradient -----------------------------------
        let (g, loss) = backend.grad(&w, data, &idx)?;

        // -- line 7: ω̄ accumulation + step size ---------------------------
        let t_red = Timer::start();
        for j in 0..n {
            wbar_acc[j] += w[j];
        }
        let alpha = sqn_alpha(cfg.beta, k);
        let mut red_s = t_red.elapsed_s();

        // -- lines 8-12: gradient or quasi-Newton step ---------------------
        let mut dir_s = 0.0f64;
        if k <= 2 * cfg.l_every || mem.is_empty() {
            let t_upd = Timer::start();
            for j in 0..n {
                w[j] -= alpha * g[j];
            }
            red_s += t_upd.elapsed_s();
        } else {
            let t_dir = Timer::start();
            let d = backend.direction(&mem, &g)?;
            dir_s = t_dir.elapsed_s();
            let t_upd = Timer::start();
            for j in 0..n {
                w[j] -= alpha * d[j];
            }
            red_s += t_upd.elapsed_s();
        }

        // -- lines 13-21: correction pairs every L iterations --------------
        if k % cfg.l_every == 0 {
            let t_pair = Timer::start();
            let mut hvp_s = 0.0f64;
            t_count += 1;
            let inv = 1.0 / cfg.l_every as f32;
            let wbar_t: Vec<f32> = wbar_acc.iter().map(|&v| v * inv).collect();
            if t_count > 0 {
                let prev = wbar_prev.as_ref().expect("t>0 ⇒ previous ω̄");
                let s_t: Vec<f32> =
                    wbar_t.iter().zip(prev).map(|(a, b)| a - b).collect();
                // line 17: Hessian subsample S_H
                let mut hrng = tree.stream(&[2, t_count as u64]);
                let hidx = hrng.sample_indices(
                    data.n_samples, cfg.hbatch.min(data.n_samples));
                // line 18: y_t = ∇²F(ω̄_t) s_t
                let t_hvp = Timer::start();
                let y_t = backend.hvp(&wbar_t, &s_t, data, &hidx)?;
                hvp_s = t_hvp.elapsed_s();
                if mem.push(&s_t, &y_t) {
                    trace.pairs_accepted += 1;
                } else {
                    trace.pairs_rejected += 1;
                }
            }
            wbar_prev = Some(wbar_t);
            wbar_acc.iter_mut().for_each(|v| *v = 0.0);
            // the pair bookkeeping minus the HVP kernel itself
            red_s += t_pair.elapsed_s() - hvp_s;
        }
        let step_s = timer.elapsed_s();
        trace.iter_s.push(step_s);
        trace.batch_loss.push(loss);

        // phase attribution, outside the timed region: the host-side
        // sub-intervals book directly; the kernel walls (grad, hvp,
        // direction) come from the backend's drained split — a backend
        // that self-attributes owns ALL its entry points, so the driver's
        // own direction/hvp call timers are only used in the fallback
        let mut step_prof = Profiler::new();
        step_prof.add(Phase::Reduce, red_s);
        step_prof.add(Phase::Dispatch, dispatch_s);
        match backend.take_profile() {
            Some(p) => {
                step_prof.merge(&p);
                step_prof.add(Phase::Dispatch,
                              step_s - p.sum() - dispatch_s - red_s);
            }
            None => {
                step_prof.add(Phase::Direction, dir_s);
                // grad + hvp kernels land here (hvp_s stays inside)
                step_prof.add(Phase::Compute,
                              step_s - dispatch_s - red_s - dir_s);
            }
        }
        trace.profile.merge(&step_prof);

        // -- convergence tracking (outside the timed region) ---------------
        if cfg.track_every > 0 && (k % cfg.track_every == 0 || k == 1) {
            let l = crate::tasks::classification::full_loss(&w, &xe, &ze);
            trace.checkpoints.push((k, l));
        }
        sink.on_step(&StepEvent {
            reps: &[rep],
            epoch: k,
            epochs: cfg.iters,
            objs: &[loss],
            live: 1,
            step_s,
            profile: step_prof,
        })?;
    }
    Ok((w, trace))
}

// ---------------------------------------------------------------------------
// Replication-batched driver: a PanelHook over the generic loop
// (DESIGN.md §11/§12)
// ---------------------------------------------------------------------------

/// Algorithm-3 hook: the whole SQN iteration — minibatch index sampling,
/// the three batched dispatches, ω̄ averaging, and the correction-memory
/// machinery — stays task-local here; the outer loop, panel tiling, and
/// wall-clock attribution come from [`run_panel`].
struct SqnHook<'a, B: ?Sized> {
    backend: &'a mut B,
    data: &'a ClassifyData,
    cfg: &'a SqnConfig,
    r: usize,
    n: usize,
    mem: BatchCorrectionMemory,
    g: Vec<f32>,
    dirs: Vec<f32>,
    // ω̄ accumulators (Algorithm 3 lines 3, 7, 15), one row per replication.
    // All per-pair-step state below is flat `[R × n]` panels allocated once
    // at hook construction, so the steady-state loop never touches the heap
    // (DESIGN.md §16).  Every replication crosses t_count = 0 at the same
    // iteration (the schedule is global), so ONE `has_prev` flag replaces
    // the old per-row `Option<Vec<f32>>`.
    wbar_acc: Vec<f32>,
    wbar_t: Vec<f32>,
    wbar_prev: Vec<f32>,
    has_prev: bool,
    s_panel: Vec<f32>,
    y_panel: Vec<f32>,
    t_count: i64,
    /// Fixed tracked-loss evaluation subsets — the same per-subtree draw
    /// the sequential path makes.
    evals: Vec<(Vec<f32>, Vec<f32>)>,
    idx: Vec<Vec<usize>>,
    hidx: Vec<Vec<usize>>,
    scratch: SampleScratch,
    checkpoints: Vec<Vec<(usize, f64)>>,
    pairs_accepted: Vec<usize>,
    pairs_rejected: Vec<usize>,
    // host-side sub-interval walls of the current step, drained by
    // collect_profile after the step's wall-clock is recorded
    dispatch_s: f64,
    red_s: f64,
    dir_s: f64,
}

impl<B: LrBatchBackend + ?Sized> PanelHook for SqnHook<'_, B> {
    fn advance(&mut self, k0: usize, panel: &mut [f32],
               trees: &[StreamTree], vals: &mut [f64]) -> Result<()> {
        let (r, n, cfg, data) = (self.r, self.n, self.cfg, self.data);
        let k = k0 + 1; // Algorithm 3 counts iterations from 1
        let w = panel;

        // -- line 5: per-replication minibatch indices ----------------------
        // (fixed-length rows + reused scratch: the same draw sequence as
        // `sample_indices`, with no per-step heap traffic)
        let t_idx = Timer::start();
        for (row, tree) in self.idx.iter_mut().zip(trees) {
            let mut rng = tree.stream(&[1, k as u64]);
            rng.sample_indices_into(data.n_samples, &mut self.scratch, row);
        }
        self.dispatch_s += t_idx.elapsed_s();

        // -- line 6: ONE batched stochastic-gradient dispatch ---------------
        self.backend.grad_batch(w, data, &self.idx, &mut self.g, vals)?;

        // -- line 7: ω̄ accumulation + step size ----------------------------
        let t_red = Timer::start();
        for j in 0..r * n {
            self.wbar_acc[j] += w[j];
        }
        let alpha = sqn_alpha(cfg.beta, k);
        self.red_s += t_red.elapsed_s();

        // -- lines 8-12: gradient or quasi-Newton step ----------------------
        if k <= 2 * cfg.l_every {
            let t_upd = Timer::start();
            for j in 0..r * n {
                w[j] -= alpha * self.g[j];
            }
            self.red_s += t_upd.elapsed_s();
        } else {
            if self.mem.any_active() {
                // ONE padded dispatch produces every replication's
                // Algorithm-4 direction (DESIGN.md §11); the backend sees
                // a borrowed view so a sharded plane can slice it per
                // shard with zero copies (DESIGN.md §13)
                let t_dir = Timer::start();
                self.backend.direction_batch(self.mem.view(), &self.g,
                                             &mut self.dirs)?;
                self.dir_s += t_dir.elapsed_s();
            }
            let t_upd = Timer::start();
            for i in 0..r {
                // rows whose memory hasn't accepted a pair yet take the
                // plain gradient step, exactly as the sequential path does
                let step = if self.mem.is_active(i) {
                    &self.dirs
                } else {
                    &self.g
                };
                for j in i * n..(i + 1) * n {
                    w[j] -= alpha * step[j];
                }
            }
            self.red_s += t_upd.elapsed_s();
        }

        // -- lines 13-21: correction pairs every L iterations ---------------
        if k % cfg.l_every == 0 {
            let t_pair = Timer::start();
            let mut hvp_s = 0.0f64;
            self.t_count += 1;
            let inv = 1.0 / cfg.l_every as f32;
            // ω̄_t = accumulated iterates / L, straight into the flat panel
            // (same per-element arithmetic as the old row-by-row collect)
            for (slot, &acc) in self.wbar_t.iter_mut().zip(&self.wbar_acc) {
                *slot = acc * inv;
            }
            if self.t_count > 0 {
                anyhow::ensure!(self.has_prev, "t>0 ⇒ previous ω̄");
                // s_t = ω̄_t − ω̄_{t−1}, and Hessian-batch indices per row
                for ((slot, &a), &b) in self.s_panel.iter_mut()
                    .zip(&self.wbar_t)
                    .zip(&self.wbar_prev)
                {
                    *slot = a - b;
                }
                for (row, tree) in self.hidx.iter_mut().zip(trees) {
                    let mut hrng = tree.stream(&[2, self.t_count as u64]);
                    hrng.sample_indices_into(data.n_samples,
                                             &mut self.scratch, row);
                }
                // line 18: ONE batched Hessian-vector dispatch
                let t_hvp = Timer::start();
                self.backend.hvp_batch(&self.wbar_t, &self.s_panel, data,
                                       &self.hidx, &mut self.y_panel)?;
                hvp_s = t_hvp.elapsed_s();
                for i in 0..r {
                    if self.mem.push_row(i,
                                         &self.s_panel[i * n..(i + 1) * n],
                                         &self.y_panel[i * n..(i + 1) * n])
                    {
                        self.pairs_accepted[i] += 1;
                    } else {
                        self.pairs_rejected[i] += 1;
                    }
                }
            }
            self.wbar_prev.copy_from_slice(&self.wbar_t);
            self.has_prev = true;
            self.wbar_acc.iter_mut().for_each(|v| *v = 0.0);
            // the pair bookkeeping minus the HVP kernel itself
            self.red_s += t_pair.elapsed_s() - hvp_s;
        }
        Ok(())
    }

    fn collect_profile(&mut self, step_s: f64, prof: &mut Profiler) {
        let dispatch_s = std::mem::take(&mut self.dispatch_s);
        let red_s = std::mem::take(&mut self.red_s);
        let dir_s = std::mem::take(&mut self.dir_s);
        prof.add(Phase::Dispatch, dispatch_s);
        prof.add(Phase::Reduce, red_s);
        match self.backend.take_profile() {
            Some(p) => {
                prof.merge(&p);
                prof.add(Phase::Dispatch,
                         step_s - p.sum() - dispatch_s - red_s);
            }
            None => {
                prof.add(Phase::Direction, dir_s);
                prof.add(Phase::Compute,
                         step_s - dispatch_s - red_s - dir_s);
            }
        }
    }

    fn observe(&mut self, k0: usize, panel: &[f32], live: &[bool])
        -> Result<()> {
        // convergence tracking, outside the timed region (as in run_sqn);
        // frozen rows' checkpoint series stop with their trace
        let (cfg, n) = (self.cfg, self.n);
        let k = k0 + 1;
        if cfg.track_every > 0 && (k % cfg.track_every == 0 || k == 1) {
            for i in 0..self.r {
                if !live[i] {
                    continue;
                }
                let (xe, ze) = &self.evals[i];
                let l = crate::tasks::classification::full_loss(
                    &panel[i * n..(i + 1) * n], xe, ze);
                self.checkpoints[i].push((k, l));
            }
        }
        Ok(())
    }
}

/// Algorithm 3 over all replications at once.  Per iteration the backend
/// sees ONE `grad_batch` call on an `[R × n]` iterate panel, ONE
/// `direction_batch` call over the padded `[R × mem × n]` correction
/// panels, and (on the Algorithm-4 schedule) ONE `hvp_batch` call —
/// zero per-replication dispatches anywhere in the loop.  Per-replication
/// state — ω̄ accumulators, correction memories (as rows of a
/// [`BatchCorrectionMemory`]), minibatch streams, the tracked-loss
/// evaluation subset — is kept exactly as [`run_sqn`] keeps it, row by
/// row, so each replication's trajectory is bit-identical to its
/// sequential run under the same subtree.
pub fn run_sqn_batch<B: LrBatchBackend + ?Sized>(
    backend: &mut B,
    data: &ClassifyData,
    cfg: &SqnConfig,
    trees: &[StreamTree],
) -> Result<(Vec<f32>, Vec<SqnTrace>)> {
    let mut sink = NullSink;
    let mut ctl = PanelCtl { sink: &mut sink, budget: None };
    let out = run_sqn_batch_ctl(backend, data, cfg, trees, &mut ctl)?;
    Ok((out.panel, out.traces))
}

/// What [`run_sqn_batch_ctl`] produced — [`super::panel::PanelOutcome`]
/// with the reassembled per-replication [`SqnTrace`]s.
#[derive(Debug, Clone)]
pub struct SqnBatchOutcome {
    pub panel: Vec<f32>,
    pub traces: Vec<SqnTrace>,
    /// `(replication, 1-based iteration)` freeze decisions.
    pub frozen: Vec<(usize, usize)>,
    /// 1-based iteration after which the run stopped early, if it did.
    pub early_stop: Option<usize>,
    /// Panel-level per-phase attribution of the whole run (DESIGN.md §15).
    pub profile: Profiler,
}

/// [`run_sqn_batch`] under a [`PanelCtl`]: per-iteration progress events
/// plus the opt-in adaptive replication budget (DESIGN.md §14).
pub fn run_sqn_batch_ctl<B: LrBatchBackend + ?Sized>(
    backend: &mut B,
    data: &ClassifyData,
    cfg: &SqnConfig,
    trees: &[StreamTree],
    ctl: &mut PanelCtl<'_>,
) -> Result<SqnBatchOutcome> {
    let r = trees.len();
    let n = data.n_features;
    anyhow::ensure!(backend.batch_reps() == r,
                    "backend built for {} replications, got {} trees",
                    backend.batch_reps(), r);

    let evals: Vec<(Vec<f32>, Vec<f32>)> = trees
        .iter()
        .map(|tree| {
            let mut rng = tree.stream(&[0xE7A1]);
            let rows = cfg.track_rows.min(data.n_samples);
            let eval_rows = rng.sample_indices(data.n_samples, rows);
            let mut xe = Vec::new();
            let mut ze = Vec::new();
            data.gather(&eval_rows, &mut xe, &mut ze);
            (xe, ze)
        })
        .collect();

    let mut hook = SqnHook {
        backend,
        data,
        cfg,
        r,
        n,
        mem: BatchCorrectionMemory::new(r, cfg.memory, n),
        g: vec![0.0f32; r * n],
        dirs: vec![0.0f32; r * n],
        wbar_acc: vec![0.0f32; r * n],
        wbar_t: vec![0.0f32; r * n],
        wbar_prev: vec![0.0f32; r * n],
        has_prev: false,
        s_panel: vec![0.0f32; r * n],
        y_panel: vec![0.0f32; r * n],
        t_count: -1,
        evals,
        idx: vec![vec![0usize; cfg.batch.min(data.n_samples)]; r],
        hidx: vec![vec![0usize; cfg.hbatch.min(data.n_samples)]; r],
        scratch: SampleScratch::for_draws(
            data.n_samples,
            cfg.batch.max(cfg.hbatch).min(data.n_samples)),
        checkpoints: vec![Vec::new(); r],
        pairs_accepted: vec![0; r],
        pairs_rejected: vec![0; r],
        dispatch_s: 0.0,
        red_s: 0.0,
        dir_s: 0.0,
    };
    let x0 = vec![0.0f32; n];
    let out = run_panel_ctl(&mut hook, &x0, cfg.iters, trees, ctl)?;

    // Reassemble SqnTraces: the generic loop recorded minibatch losses and
    // wall-clock shares; checkpoints and pair counts are hook state.
    let mut traces = Vec::with_capacity(r);
    for (i, ft) in out.traces.into_iter().enumerate() {
        traces.push(SqnTrace {
            checkpoints: std::mem::take(&mut hook.checkpoints[i]),
            batch_loss: ft.objs,
            iter_s: ft.epoch_s,
            pairs_accepted: hook.pairs_accepted[i],
            pairs_rejected: hook.pairs_rejected[i],
            profile: Profiler::default(),
        });
    }
    Ok(SqnBatchOutcome {
        panel: out.panel,
        traces,
        frozen: out.frozen,
        early_stop: out.early_stop,
        profile: out.profile,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::native::{NativeLr, NativeMode};
    use crate::backend::HessianMode;

    fn small_cfg(iters: usize) -> SqnConfig {
        SqnConfig {
            iters,
            batch: 32,
            hbatch: 64,
            l_every: 5,
            memory: 4,
            beta: 2.0,
            track_every: 10,
            track_rows: 512,
        }
    }

    #[test]
    fn sqn_reduces_loss() {
        let tree = StreamTree::new(21);
        let data = ClassifyData::generate(&tree, 24);
        let mut b = NativeLr::new(&data, NativeMode::Sequential,
                                  HessianMode::Explicit);
        let (w, trace) = run_sqn(&mut b, &data, &small_cfg(120), &tree).unwrap();
        assert_eq!(w.len(), 24);
        let first = trace.checkpoints.first().unwrap().1;
        let last = trace.checkpoints.last().unwrap().1;
        assert!(last < first, "loss {} !< {}", last, first);
        assert!(last < 0.6, "should beat chance-level BCE, got {}", last);
        assert!(trace.pairs_accepted > 0);
    }

    #[test]
    fn sqn_enters_quasi_newton_phase() {
        let tree = StreamTree::new(22);
        let data = ClassifyData::generate(&tree, 16);
        let mut b = NativeLr::new(&data, NativeMode::Sequential,
                                  HessianMode::TwoLoop);
        let cfg = small_cfg(40);
        let (_, trace) = run_sqn(&mut b, &data, &cfg, &tree).unwrap();
        // after 2L = 10 iterations pairs start accumulating every L
        assert!(trace.pairs_accepted + trace.pairs_rejected >= 5);
        assert_eq!(trace.iter_s.len(), 40);
        assert_eq!(trace.batch_loss.len(), 40);
    }

    #[test]
    fn sqn_deterministic_given_tree() {
        let tree = StreamTree::new(23);
        let data = ClassifyData::generate(&tree, 12);
        let run = || {
            let mut b = NativeLr::new(&data, NativeMode::Sequential,
                                      HessianMode::Explicit);
            run_sqn(&mut b, &data, &small_cfg(30), &tree).unwrap()
        };
        let (w1, t1) = run();
        let (w2, t2) = run();
        assert_eq!(w1, w2);
        assert_eq!(t1.batch_loss, t2.batch_loss);
    }

    #[test]
    fn explicit_and_twoloop_converge_similarly() {
        let tree = StreamTree::new(24);
        let data = ClassifyData::generate(&tree, 16);
        let cfg = small_cfg(100);
        let mut be = NativeLr::new(&data, NativeMode::Sequential,
                                   HessianMode::Explicit);
        let mut bt = NativeLr::new(&data, NativeMode::Sequential,
                                   HessianMode::TwoLoop);
        let (_, te) = run_sqn(&mut be, &data, &cfg, &tree).unwrap();
        let (_, tt) = run_sqn(&mut bt, &data, &cfg, &tree).unwrap();
        let le = te.checkpoints.last().unwrap().1;
        let lt = tt.checkpoints.last().unwrap().1;
        assert!((le - lt).abs() < 0.05, "explicit {} vs twoloop {}", le, lt);
    }

    #[test]
    fn sqn_batch_driver_matches_sequential_driver_bitwise() {
        use crate::backend::native::NativeLrBatch;
        let n = 12usize;
        let reps = 3usize;
        let root = StreamTree::new(25);
        let data = ClassifyData::generate(&root, n);
        let cfg = small_cfg(35);
        let trees: Vec<StreamTree> =
            (0..reps).map(|r| root.subtree(&[1000 + r as u64])).collect();

        let mut batch =
            NativeLrBatch::new(&data, reps, 2, HessianMode::Explicit);
        let (w_panel, traces) =
            run_sqn_batch(&mut batch, &data, &cfg, &trees).unwrap();

        for (r, tree) in trees.iter().enumerate() {
            let mut single = NativeLr::new(&data, NativeMode::Sequential,
                                           HessianMode::Explicit);
            let (w_seq, t_seq) =
                run_sqn(&mut single, &data, &cfg, tree).unwrap();
            assert_eq!(&w_panel[r * n..(r + 1) * n], w_seq.as_slice(),
                       "rep {}", r);
            assert_eq!(traces[r].batch_loss, t_seq.batch_loss, "rep {}", r);
            assert_eq!(traces[r].checkpoints, t_seq.checkpoints, "rep {}", r);
            assert_eq!(traces[r].pairs_accepted, t_seq.pairs_accepted);
            assert_eq!(traces[r].pairs_rejected, t_seq.pairs_rejected);
        }
    }
}
