//! The ONE generic replication-panel loop behind every batched driver
//! (DESIGN.md §11/§12).
//!
//! All batched execution in this repo has the same skeleton: tile the
//! start iterate into an `[R × n]` row-major panel (row r = replication
//! r), advance every row one outer step per iteration through a
//! task-specific hook, and attribute each step's wall-clock to the
//! per-replication traces as `batch_time / R`.  What differs per
//! task — key derivation, inner Frank-Wolfe iterations, the
//! pool-parallel panel LMO (DESIGN.md §17), the SQN correction-memory
//! machinery — lives entirely behind [`PanelHook`], so
//! `opt::{run_mv_batch, run_nv_batch, run_sqn_batch}` are thin wrappers
//! and a new scenario's batched driver is one hook, not a new loop.
//!
//! The loop is also shard-agnostic: sharded execution (DESIGN.md §13)
//! happens entirely inside the backend — `backend::plane::ShardedBatch`
//! implements the same `*BatchBackend` traits the hooks drive, so NO
//! sharding code exists in any driver or hook.
//!
//! [`run_panel_ctl`] is the controlled variant (DESIGN.md §14): it
//! reports every step to a [`ProgressSink`] and optionally applies a
//! [`BudgetPolicy`] — at epoch checkpoints, replications whose objective
//! clearly trails the best live row freeze (their panel rows are pinned,
//! masked not resliced, so backends keep dispatching full `[R × n]`
//! panels and shard shapes never change), and the run stops early once
//! every survivor's objective has stopped moving.  [`run_panel`] is the
//! uncontrolled wrapper (null sink, no budget) with the original
//! signature.

use anyhow::Result;

use crate::config::BudgetPolicy;
use crate::rng::StreamTree;
use crate::util::profile::{Phase, Profiler};
use crate::util::timer::Timer;

use super::frank_wolfe::FwTrace;
use super::progress::{NullSink, ProgressSink, StepEvent};

/// Task-specific hook driven once per outer step by [`run_panel`].
pub trait PanelHook {
    /// Untimed per-step preparation (e.g. deriving per-replication stream
    /// keys) — runs BEFORE the step's wall-clock measurement starts,
    /// mirroring the sequential drivers' key-outside-the-timer discipline
    /// so batched and sequential totals stay comparable (DESIGN.md §11).
    fn prepare(&mut self, _k: usize, _trees: &[StreamTree]) -> Result<()> {
        Ok(())
    }

    /// Advance every replication row by one outer step (the TIMED region).
    /// `panel` is the `[R × n]` iterate panel; `trees[r]` is replication
    /// r's stream subtree — the SAME subtree the sequential driver
    /// receives, so batched and sequential runs stay bit-identical.
    /// Writes the per-row value recorded for this step (the epoch
    /// objective for FW tasks, the minibatch loss for SQN) into `vals`
    /// (length R) — an out-param owned by the loop so the steady-state
    /// step allocates nothing (DESIGN.md §16).
    fn advance(&mut self, k: usize, panel: &mut [f32],
               trees: &[StreamTree], vals: &mut [f64]) -> Result<()>;

    /// Untimed per-step observation (e.g. SQN tracked-loss checkpoints);
    /// runs after `advance`'s wall-clock has been recorded, mirroring the
    /// sequential drivers' tracking-outside-the-timed-region discipline.
    /// `live[r]` is false once a budget policy froze replication r — a
    /// hook must not extend frozen rows' observations.
    fn observe(&mut self, _k: usize, _panel: &[f32], _live: &[bool])
        -> Result<()> {
        Ok(())
    }

    /// Attribute the step's timed wall (`step_s`, the `advance` region
    /// just measured) to `prof`'s phases (DESIGN.md §15).  Runs OUTSIDE
    /// the timed region, right after it — a hook that timed sub-intervals
    /// during `advance` books them here and drains its backend's own
    /// dispatch/compute split; the default books the whole wall as
    /// `compute`.  The phase totals of one step must sum to `step_s` (up
    /// to clock noise on the residual), never more.
    fn collect_profile(&mut self, step_s: f64, prof: &mut Profiler) {
        prof.add(Phase::Compute, step_s);
    }
}

/// Observer + budget for one [`run_panel_ctl`] run.
pub struct PanelCtl<'a> {
    /// Per-step observer (use [`NullSink`] for none).
    pub sink: &'a mut dyn ProgressSink,
    /// Opt-in adaptive replication budget; `None` runs every row for
    /// every step (the bitwise seq==batch contract).
    pub budget: Option<BudgetPolicy>,
}

/// What a controlled panel run produced.
#[derive(Debug, Clone)]
pub struct PanelOutcome {
    /// Final `[R × n]` iterate panel (frozen rows hold their pinned
    /// iterate).
    pub panel: Vec<f32>,
    /// One trace per replication; frozen rows' traces end at their
    /// freeze epoch.
    pub traces: Vec<FwTrace>,
    /// `(replication, 1-based epoch)` freeze decisions, in decision
    /// order — recorded in `RunResult` so a budgeted run is reproducible
    /// from its payload.
    pub frozen: Vec<(usize, usize)>,
    /// 1-based epoch after which the run stopped early (all survivors
    /// converged), if it did.
    pub early_stop: Option<usize>,
    /// Per-phase wall-clock attribution accumulated over every step
    /// (DESIGN.md §15).
    pub profile: Profiler,
}

/// Attribute one batched-call wall-clock to the live per-replication
/// traces as `batch_s / R` (DESIGN.md §11/§14).  The divisor is the
/// FULL row count, not the live count: frozen rows are masked, not
/// resliced, so the backend advances all R rows every step and each
/// row's true per-step cost is the full-panel share — dividing by the
/// live count instead would inflate survivors' timings as rows freeze
/// and make a budgeted run's traces incomparable to an unbudgeted run
/// of the same spec.  Frozen rows' shares go unattributed (their traces
/// ended at the freeze), so under a budget the attributed total
/// undercounts the batch wall-clock: a freeze saves no per-step
/// compute; the budget's savings come from early stop.  The
/// cross-replication timing band is methodologically n/a either way —
/// see `coordinator::report`.
pub(crate) fn push_step(traces: &mut [FwTrace], vals: &[f64], batch_s: f64,
                        live: &[bool]) {
    let share = batch_s / live.len().max(1) as f64;
    for ((trace, &v), &l) in traces.iter_mut().zip(vals).zip(live) {
        if l {
            trace.epoch_s.push(share);
            trace.objs.push(v);
        }
    }
}

/// Run `steps` outer steps of `hook` over the replication panel tiled
/// from `x0`, one row per subtree in `trees`.  Returns the final panel
/// and one per-replication trace of (recorded value, wall-clock share)
/// per step.  Equivalent to [`run_panel_ctl`] with a null sink and no
/// budget.
pub fn run_panel<H: PanelHook + ?Sized>(
    hook: &mut H,
    x0: &[f32],
    steps: usize,
    trees: &[StreamTree],
) -> Result<(Vec<f32>, Vec<FwTrace>)> {
    let mut sink = NullSink;
    let mut ctl = PanelCtl { sink: &mut sink, budget: None };
    let out = run_panel_ctl(hook, x0, steps, trees, &mut ctl)?;
    Ok((out.panel, out.traces))
}

/// The controlled panel loop: [`run_panel`] plus per-step progress events
/// and the opt-in adaptive replication budget (DESIGN.md §14).
///
/// With `ctl.budget == None` the loop is bit-identical to [`run_panel`]
/// (the sink observes AFTER each step's timed region and never touches
/// the panel).  With a budget, at every `check_every`-th epoch the live
/// rows' recorded values are compared: rows trailing the best live row
/// by more than `gap` (relative) freeze — their panel row is pinned and
/// restored after every subsequent `advance`, so backends keep seeing
/// full-shape panels (masked, not resliced) while the frozen trajectory
/// stops moving and its trace stops growing.  Once all survivors'
/// values have moved at most `tol` (relative) since the previous
/// checkpoint, the loop stops early.
pub fn run_panel_ctl<H: PanelHook + ?Sized>(
    hook: &mut H,
    x0: &[f32],
    steps: usize,
    trees: &[StreamTree],
    ctl: &mut PanelCtl<'_>,
) -> Result<PanelOutcome> {
    let r = trees.len();
    let n = x0.len();
    if let Some(b) = &ctl.budget {
        anyhow::ensure!(b.check_every > 0,
                        "budget check_every must be positive");
        anyhow::ensure!(b.gap.is_finite() && b.gap >= 0.0,
                        "budget gap must be finite and non-negative");
        anyhow::ensure!(b.tol.is_finite() && b.tol >= 0.0,
                        "budget tol must be finite and non-negative");
    }
    let mut panel = crate::backend::plane::tile_rows(x0, r);
    let mut traces = vec![FwTrace::default(); r];
    for t in traces.iter_mut() {
        // full-run capacity up front so the steady-state pushes in
        // push_step never reallocate (DESIGN.md §16)
        t.objs.reserve(steps);
        t.epoch_s.reserve(steps);
    }
    // per-row step values, written in place by the hook every step
    let mut vals = vec![f64::NAN; r];
    let mut live = vec![true; r];
    let mut frozen: Vec<(usize, usize)> = Vec::new();
    let mut early_stop = None;
    // pinned iterates of frozen rows, restored after every advance
    let mut pinned: Option<Vec<f32>> = None;
    // per-row value at the previous budget checkpoint
    let mut last_ck = vec![f64::NAN; r];
    let mut have_ck = false;
    // scratch for the per-step progress event
    let mut ev_reps: Vec<usize> = Vec::with_capacity(r);
    let mut ev_objs: Vec<f64> = Vec::with_capacity(r);

    let mut profile = Profiler::new();
    for k in 0..steps {
        hook.prepare(k, trees)?;
        let t = Timer::start();
        hook.advance(k, &mut panel, trees, &mut vals)?;
        let step_s = t.elapsed_s();
        // phase attribution happens OUTSIDE the timed region, so the
        // recorded step_s (and every trace bit) matches an unprofiled run
        let mut step_prof = Profiler::new();
        hook.collect_profile(step_s, &mut step_prof);
        // mask frozen rows: the backend advanced the whole panel (shard
        // shapes are sacred), the loop pins the frozen iterates back
        if let Some(pin) = &pinned {
            for (i, l) in live.iter().enumerate() {
                if !l {
                    panel[i * n..(i + 1) * n]
                        .copy_from_slice(&pin[i * n..(i + 1) * n]);
                }
            }
        }
        push_step(&mut traces, &vals, step_s, &live);
        hook.observe(k, &panel, &live)?;

        // the snapshot covers the rows that were live during this step
        ev_reps.clear();
        ev_objs.clear();
        for (i, &l) in live.iter().enumerate() {
            if l {
                ev_reps.push(i);
                ev_objs.push(vals[i]);
            }
        }

        // budget checkpoint (never at the final epoch — nothing left to
        // save)
        let epoch = k + 1;
        let t_ck = Timer::start();
        if let Some(b) = &ctl.budget {
            if epoch % b.check_every == 0 && epoch < steps {
                let best = ev_objs.iter().cloned().fold(f64::INFINITY,
                                                        f64::min);
                let scale = b.gap * best.abs().max(1e-12);
                for (&i, &v) in ev_reps.iter().zip(&ev_objs) {
                    if v - best > scale {
                        live[i] = false;
                        frozen.push((i, epoch));
                        let pin = pinned.get_or_insert_with(
                            || vec![0.0f32; r * n]);
                        pin[i * n..(i + 1) * n]
                            .copy_from_slice(&panel[i * n..(i + 1) * n]);
                    }
                }
                if have_ck {
                    // same small-magnitude floor as the gap rule: tol is
                    // genuinely relative (the 1e-12 floor only guards
                    // v == 0), so objectives at loss scales ≪ 1 converge
                    // on relative movement, not a hidden absolute one
                    let converged = ev_reps.iter().zip(&ev_objs).all(
                        |(&i, &v)| {
                            !live[i]
                                || (v - last_ck[i]).abs()
                                    <= b.tol * v.abs().max(1e-12)
                        });
                    let any_live = live.iter().any(|&l| l);
                    if converged && any_live {
                        early_stop = Some(epoch);
                    }
                }
                for (&i, &v) in ev_reps.iter().zip(&ev_objs) {
                    last_ck[i] = v;
                }
                have_ck = true;
            }
        }

        if ctl.budget.is_some() {
            step_prof.add(Phase::FreezeCheck, t_ck.elapsed_s());
        }
        profile.merge(&step_prof);

        let n_live = live.iter().filter(|&&l| l).count();
        ctl.sink.on_step(&StepEvent {
            reps: &ev_reps,
            epoch,
            epochs: steps,
            objs: &ev_objs,
            live: n_live,
            step_s,
            profile: step_prof,
        })?;
        if early_stop.is_some() {
            break;
        }
    }
    Ok(PanelOutcome { panel, traces, frozen, early_stop, profile })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Hook that decrements every row by its replication index per step.
    struct CountingHook {
        prepared: usize,
        advanced: Vec<usize>,
        observed: usize,
    }

    impl PanelHook for CountingHook {
        fn prepare(&mut self, _k: usize, _trees: &[StreamTree])
            -> Result<()> {
            // must run before the matching advance
            assert_eq!(self.prepared, self.advanced.len());
            self.prepared += 1;
            Ok(())
        }

        fn advance(&mut self, k: usize, panel: &mut [f32],
                   trees: &[StreamTree], vals: &mut [f64]) -> Result<()> {
            self.advanced.push(k);
            let n = panel.len() / trees.len();
            for (r, row) in panel.chunks_mut(n).enumerate() {
                for v in row.iter_mut() {
                    *v -= r as f32;
                }
            }
            for (r, slot) in vals.iter_mut().enumerate() {
                *slot = (k * 10 + r) as f64;
            }
            Ok(())
        }

        fn observe(&mut self, _k: usize, _panel: &[f32], _live: &[bool])
            -> Result<()> {
            self.observed += 1;
            Ok(())
        }
    }

    #[test]
    fn panel_loop_tiles_advances_and_records() {
        let trees: Vec<StreamTree> =
            (0..3).map(|i| StreamTree::new(i)).collect();
        let mut hook =
            CountingHook { prepared: 0, advanced: Vec::new(), observed: 0 };
        let (panel, traces) =
            run_panel(&mut hook, &[1.0, 2.0], 4, &trees).unwrap();
        assert_eq!(hook.prepared, 4);
        assert_eq!(hook.advanced, vec![0, 1, 2, 3]);
        assert_eq!(hook.observed, 4);
        assert_eq!(panel.len(), 6);
        // row r = x0 − 4·r
        assert_eq!(&panel[..2], &[1.0, 2.0]);
        assert_eq!(&panel[2..4], &[-3.0, -2.0]);
        assert_eq!(&panel[4..6], &[-7.0, -6.0]);
        assert_eq!(traces.len(), 3);
        for (r, t) in traces.iter().enumerate() {
            assert_eq!(t.objs,
                       vec![r as f64, (10 + r) as f64, (20 + r) as f64,
                            (30 + r) as f64]);
            assert_eq!(t.epoch_s.len(), 4);
        }
    }

    /// A failing hook surfaces its error instead of panicking.
    struct FailingHook;

    impl PanelHook for FailingHook {
        fn advance(&mut self, _k: usize, _panel: &mut [f32],
                   _trees: &[StreamTree], _vals: &mut [f64]) -> Result<()> {
            anyhow::bail!("boom")
        }
    }

    #[test]
    fn hook_errors_propagate() {
        let trees = vec![StreamTree::new(1)];
        let err = run_panel(&mut FailingHook, &[0.0], 1, &trees).unwrap_err();
        assert!(format!("{:#}", err).contains("boom"));
    }

    /// Hook with a fixed per-row objective schedule: row r's value at
    /// step k is `base[r] + slope[r] * k`; every advance also decrements
    /// every row by 1 so frozen-row masking is visible in the panel.
    struct ScheduleHook {
        base: Vec<f64>,
        slope: Vec<f64>,
    }

    impl PanelHook for ScheduleHook {
        fn advance(&mut self, k: usize, panel: &mut [f32],
                   _trees: &[StreamTree], vals: &mut [f64]) -> Result<()> {
            for v in panel.iter_mut() {
                *v -= 1.0;
            }
            for ((slot, b), s) in
                vals.iter_mut().zip(&self.base).zip(&self.slope)
            {
                *slot = b + s * k as f64;
            }
            Ok(())
        }
    }

    struct RecordingSink(Vec<(usize, usize)>); // (epoch, live)

    impl ProgressSink for RecordingSink {
        fn on_step(&mut self, ev: &StepEvent<'_>) -> Result<()> {
            self.0.push((ev.epoch, ev.live));
            Ok(())
        }
    }

    #[test]
    fn ctl_without_budget_matches_run_panel_and_streams_every_step() {
        let trees: Vec<StreamTree> =
            (0..2).map(|i| StreamTree::new(i)).collect();
        let mut hook =
            ScheduleHook { base: vec![1.0, 2.0], slope: vec![0.0, 0.0] };
        let (panel, traces) =
            run_panel(&mut hook, &[0.0], 3, &trees).unwrap();
        let mut hook =
            ScheduleHook { base: vec![1.0, 2.0], slope: vec![0.0, 0.0] };
        let mut sink = RecordingSink(Vec::new());
        let mut ctl = PanelCtl { sink: &mut sink, budget: None };
        let out = run_panel_ctl(&mut hook, &[0.0], 3, &trees, &mut ctl)
            .unwrap();
        assert_eq!(out.panel, panel);
        assert_eq!(out.traces.len(), traces.len());
        for (a, b) in out.traces.iter().zip(&traces) {
            assert_eq!(a.objs, b.objs);
        }
        assert!(out.frozen.is_empty());
        assert_eq!(out.early_stop, None);
        assert_eq!(sink.0, vec![(1, 2), (2, 2), (3, 2)]);
    }

    #[test]
    fn budget_freezes_dominated_rows_and_pins_their_panel() {
        let trees: Vec<StreamTree> =
            (0..3).map(|i| StreamTree::new(i)).collect();
        // row 2 trails rows 0/1 by far more than the gap from step one
        let mut hook = ScheduleHook {
            base: vec![1.0, 1.01, 50.0],
            slope: vec![-0.001, -0.001, 0.0],
        };
        let mut sink = RecordingSink(Vec::new());
        let mut ctl = PanelCtl {
            sink: &mut sink,
            budget: Some(BudgetPolicy { check_every: 2, gap: 0.5,
                                        tol: 0.0 }),
        };
        let out = run_panel_ctl(&mut hook, &[0.0], 6, &trees, &mut ctl)
            .unwrap();
        assert_eq!(out.frozen, vec![(2, 2)]);
        // frozen at epoch 2 ⇒ its trace has exactly 2 entries, survivors
        // keep recording
        assert_eq!(out.traces[2].objs.len(), 2);
        assert!(out.traces[0].objs.len() > 2);
        // panel row 2 pinned at −2 (two decrements), survivors kept moving
        assert_eq!(out.panel[0], -(out.traces[0].objs.len() as f32));
        assert_eq!(out.panel[2], -2.0);
        // the sink saw the live count drop after the checkpoint
        assert_eq!(sink.0[0], (1, 3));
        assert_eq!(sink.0[1], (2, 2));
    }

    #[test]
    fn budget_stops_early_when_survivors_converge() {
        let trees: Vec<StreamTree> =
            (0..2).map(|i| StreamTree::new(i)).collect();
        // both rows constant ⇒ converged at the second checkpoint
        let mut hook =
            ScheduleHook { base: vec![1.0, 1.0], slope: vec![0.0, 0.0] };
        let mut sink = RecordingSink(Vec::new());
        let mut ctl = PanelCtl {
            sink: &mut sink,
            budget: Some(BudgetPolicy { check_every: 2, gap: 10.0,
                                        tol: 1e-9 }),
        };
        let out = run_panel_ctl(&mut hook, &[0.0], 20, &trees, &mut ctl)
            .unwrap();
        assert_eq!(out.early_stop, Some(4));
        assert!(out.frozen.is_empty());
        assert_eq!(out.traces[0].objs.len(), 4);
        assert_eq!(sink.0.len(), 4);
    }

    #[test]
    fn early_stop_tolerance_stays_relative_for_small_magnitudes() {
        let trees: Vec<StreamTree> =
            (0..2).map(|i| StreamTree::new(i)).collect();
        // objectives at loss scale ~1e-4, each checkpoint moving ~0.2%
        // relative: far from converged at tol 1e-6 even though the
        // absolute movement (2e-7) is tiny.  An absolute floor of 1.0
        // (the old `max(|v|, 1.0)` scaling) would have stopped the run
        // at the second checkpoint.
        let mut hook = ScheduleHook {
            base: vec![1e-4, 1e-4],
            slope: vec![-1e-7, -1e-7],
        };
        let mut sink = NullSink;
        let mut ctl = PanelCtl {
            sink: &mut sink,
            budget: Some(BudgetPolicy { check_every: 2, gap: 10.0,
                                        tol: 1e-6 }),
        };
        let out = run_panel_ctl(&mut hook, &[0.0], 8, &trees, &mut ctl)
            .unwrap();
        assert_eq!(out.early_stop, None);
        assert_eq!(out.traces[0].objs.len(), 8);
    }

    struct StepSecondsSink(Vec<f64>);

    impl ProgressSink for StepSecondsSink {
        fn on_step(&mut self, ev: &StepEvent<'_>) -> Result<()> {
            self.0.push(ev.step_s);
            Ok(())
        }
    }

    #[test]
    fn survivor_timings_stay_full_panel_shares_after_a_freeze() {
        let trees: Vec<StreamTree> =
            (0..3).map(|i| StreamTree::new(i)).collect();
        // row 2 freezes at the first checkpoint; the backend still
        // advances the full 3-row panel afterwards, so every step's
        // share stays batch_s / 3 — a survivor's trace must not inflate
        // to batch_s / 2 once a row freezes
        let mut hook = ScheduleHook {
            base: vec![1.0, 1.01, 50.0],
            slope: vec![-0.001, -0.001, 0.0],
        };
        let mut sink = StepSecondsSink(Vec::new());
        let mut ctl = PanelCtl {
            sink: &mut sink,
            budget: Some(BudgetPolicy { check_every: 2, gap: 0.5,
                                        tol: 0.0 }),
        };
        let out = run_panel_ctl(&mut hook, &[0.0], 6, &trees, &mut ctl)
            .unwrap();
        assert_eq!(out.frozen, vec![(2, 2)]);
        assert_eq!(out.traces[0].epoch_s.len(), 6);
        for (k, &share) in out.traces[0].epoch_s.iter().enumerate() {
            // bitwise: the loop computes the identical batch_s / 3.0
            assert_eq!(share.to_bits(), (sink.0[k] / 3.0).to_bits(),
                       "epoch {} share must be the full-panel third", k);
        }
    }

    #[test]
    fn default_profile_books_the_whole_wall_as_compute() {
        let trees: Vec<StreamTree> =
            (0..2).map(|i| StreamTree::new(i)).collect();
        let mut hook =
            ScheduleHook { base: vec![1.0, 2.0], slope: vec![0.0, 0.0] };
        let mut sink = StepSecondsSink(Vec::new());
        let mut ctl = PanelCtl { sink: &mut sink, budget: None };
        let out = run_panel_ctl(&mut hook, &[0.0], 3, &trees, &mut ctl)
            .unwrap();
        // a hook without collect_profile books every step wall as
        // compute — bitwise, since both sides sum the same f64s in order
        let wall: f64 = sink.0.iter().sum();
        assert_eq!(out.profile.get(Phase::Compute).to_bits(),
                   wall.to_bits());
        assert_eq!(out.profile.sum().to_bits(), wall.to_bits());
        assert_eq!(out.profile.get(Phase::FreezeCheck), 0.0,
                   "no budget ⇒ no freeze_check phase");
    }

    #[test]
    fn budget_never_freezes_every_row() {
        let trees: Vec<StreamTree> =
            (0..2).map(|i| StreamTree::new(i)).collect();
        let mut hook =
            ScheduleHook { base: vec![1.0, 9.0], slope: vec![-0.01, 0.0] };
        let mut sink = RecordingSink(Vec::new());
        let mut ctl = PanelCtl {
            sink: &mut sink,
            budget: Some(BudgetPolicy { check_every: 1, gap: 0.1,
                                        tol: 0.0 }),
        };
        let out = run_panel_ctl(&mut hook, &[0.0], 4, &trees, &mut ctl)
            .unwrap();
        // the best live row never trails itself: it survives to the end
        assert_eq!(out.frozen, vec![(1, 1)]);
        assert_eq!(out.traces[0].objs.len(), 4);
    }
}
