//! The ONE generic replication-panel loop behind every batched driver
//! (DESIGN.md §11/§12).
//!
//! All batched execution in this repo has the same skeleton: tile the
//! start iterate into an `[R × n]` row-major panel (row r = replication
//! r), advance every row one outer step per iteration through a
//! task-specific hook, and attribute each step's wall-clock to the
//! per-replication traces as `batch_time / R`.  What differs per task —
//! key derivation, inner Frank-Wolfe iterations, LP LMO solves, the SQN
//! correction-memory machinery — lives entirely behind [`PanelHook`], so
//! `opt::{run_mv_batch, run_nv_batch, run_sqn_batch}` are thin wrappers
//! and a new scenario's batched driver is one hook, not a new loop.
//!
//! The loop is also shard-agnostic: sharded execution (DESIGN.md §13)
//! happens entirely inside the backend — `backend::plane::ShardedBatch`
//! implements the same `*BatchBackend` traits the hooks drive, so NO
//! sharding code exists in any driver or hook.

use anyhow::Result;

use crate::rng::StreamTree;
use crate::util::timer::Timer;

use super::frank_wolfe::FwTrace;

/// Task-specific hook driven once per outer step by [`run_panel`].
pub trait PanelHook {
    /// Untimed per-step preparation (e.g. deriving per-replication stream
    /// keys) — runs BEFORE the step's wall-clock measurement starts,
    /// mirroring the sequential drivers' key-outside-the-timer discipline
    /// so batched and sequential totals stay comparable (DESIGN.md §11).
    fn prepare(&mut self, _k: usize, _trees: &[StreamTree]) -> Result<()> {
        Ok(())
    }

    /// Advance every replication row by one outer step (the TIMED region).
    /// `panel` is the `[R × n]` iterate panel; `trees[r]` is replication
    /// r's stream subtree — the SAME subtree the sequential driver
    /// receives, so batched and sequential runs stay bit-identical.
    /// Returns the per-row value recorded for this step (the epoch
    /// objective for FW tasks, the minibatch loss for SQN).
    fn advance(&mut self, k: usize, panel: &mut [f32],
               trees: &[StreamTree]) -> Result<Vec<f64>>;

    /// Untimed per-step observation (e.g. SQN tracked-loss checkpoints);
    /// runs after `advance`'s wall-clock has been recorded, mirroring the
    /// sequential drivers' tracking-outside-the-timed-region discipline.
    fn observe(&mut self, _k: usize, _panel: &[f32]) -> Result<()> {
        Ok(())
    }
}

/// Distribute one batched-call wall-clock across the per-replication
/// traces (total batched time == sum over replications stays comparable
/// with the sequential protocol's per-replication totals; the
/// cross-replication timing band is methodologically n/a — see
/// `coordinator::report`).
pub(crate) fn push_step(traces: &mut [FwTrace], vals: &[f64], batch_s: f64) {
    let share = batch_s / traces.len().max(1) as f64;
    for (trace, &v) in traces.iter_mut().zip(vals) {
        trace.epoch_s.push(share);
        trace.objs.push(v);
    }
}

/// Run `steps` outer steps of `hook` over the replication panel tiled
/// from `x0`, one row per subtree in `trees`.  Returns the final panel
/// and one per-replication trace of (recorded value, wall-clock share)
/// per step.
pub fn run_panel<H: PanelHook + ?Sized>(
    hook: &mut H,
    x0: &[f32],
    steps: usize,
    trees: &[StreamTree],
) -> Result<(Vec<f32>, Vec<FwTrace>)> {
    let r = trees.len();
    let mut panel = crate::backend::plane::tile_rows(x0, r);
    let mut traces = vec![FwTrace::default(); r];
    for k in 0..steps {
        hook.prepare(k, trees)?;
        let t = Timer::start();
        let vals = hook.advance(k, &mut panel, trees)?;
        anyhow::ensure!(vals.len() == r,
                        "hook returned {} values for {} replications",
                        vals.len(), r);
        push_step(&mut traces, &vals, t.elapsed_s());
        hook.observe(k, &panel)?;
    }
    Ok((panel, traces))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Hook that decrements every row by its replication index per step.
    struct CountingHook {
        prepared: usize,
        advanced: Vec<usize>,
        observed: usize,
    }

    impl PanelHook for CountingHook {
        fn prepare(&mut self, _k: usize, _trees: &[StreamTree])
            -> Result<()> {
            // must run before the matching advance
            assert_eq!(self.prepared, self.advanced.len());
            self.prepared += 1;
            Ok(())
        }

        fn advance(&mut self, k: usize, panel: &mut [f32],
                   trees: &[StreamTree]) -> Result<Vec<f64>> {
            self.advanced.push(k);
            let n = panel.len() / trees.len();
            for (r, row) in panel.chunks_mut(n).enumerate() {
                for v in row.iter_mut() {
                    *v -= r as f32;
                }
            }
            Ok((0..trees.len()).map(|r| (k * 10 + r) as f64).collect())
        }

        fn observe(&mut self, _k: usize, _panel: &[f32]) -> Result<()> {
            self.observed += 1;
            Ok(())
        }
    }

    #[test]
    fn panel_loop_tiles_advances_and_records() {
        let trees: Vec<StreamTree> =
            (0..3).map(|i| StreamTree::new(i)).collect();
        let mut hook =
            CountingHook { prepared: 0, advanced: Vec::new(), observed: 0 };
        let (panel, traces) =
            run_panel(&mut hook, &[1.0, 2.0], 4, &trees).unwrap();
        assert_eq!(hook.prepared, 4);
        assert_eq!(hook.advanced, vec![0, 1, 2, 3]);
        assert_eq!(hook.observed, 4);
        assert_eq!(panel.len(), 6);
        // row r = x0 − 4·r
        assert_eq!(&panel[..2], &[1.0, 2.0]);
        assert_eq!(&panel[2..4], &[-3.0, -2.0]);
        assert_eq!(&panel[4..6], &[-7.0, -6.0]);
        assert_eq!(traces.len(), 3);
        for (r, t) in traces.iter().enumerate() {
            assert_eq!(t.objs,
                       vec![r as f64, (10 + r) as f64, (20 + r) as f64,
                            (30 + r) as f64]);
            assert_eq!(t.epoch_s.len(), 4);
        }
    }

    /// A failing hook surfaces its error instead of panicking.
    struct FailingHook;

    impl PanelHook for FailingHook {
        fn advance(&mut self, _k: usize, _panel: &mut [f32],
                   _trees: &[StreamTree]) -> Result<Vec<f64>> {
            anyhow::bail!("boom")
        }
    }

    #[test]
    fn hook_errors_propagate() {
        let trees = vec![StreamTree::new(1)];
        let err = run_panel(&mut FailingHook, &[0.0], 1, &trees).unwrap_err();
        assert!(format!("{:#}", err).contains("boom"));
    }

    /// Wrong hook arity is caught by the loop, not silently zipped away.
    struct ShortHook;

    impl PanelHook for ShortHook {
        fn advance(&mut self, _k: usize, _panel: &mut [f32],
                   _trees: &[StreamTree]) -> Result<Vec<f64>> {
            Ok(vec![0.0]) // one value for two replications
        }
    }

    #[test]
    fn wrong_value_count_rejected() {
        let trees = vec![StreamTree::new(1), StreamTree::new(2)];
        assert!(run_panel(&mut ShortHook, &[0.0], 1, &trees).is_err());
    }
}
