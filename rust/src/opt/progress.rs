//! The execution plane's observer hook (DESIGN.md §14).
//!
//! Streaming sessions need per-epoch objective snapshots out of the
//! drivers without the drivers learning anything about serving.  The
//! contract is one trait: a [`ProgressSink`] receives a [`StepEvent`]
//! after every outer step — from the generic replication-panel loop
//! (one event covering all live rows) and from the sequential drivers
//! (one event per replication per epoch).  The coordinator threads a
//! sink through `run_with`; the service's worker adapts it onto the
//! per-job reply channel; everything below the coordinator stays
//! serving-agnostic.
//!
//! Sink calls happen OUTSIDE the timed regions (after the step's
//! wall-clock has been recorded), so an attached observer never
//! perturbs the reported timings, and a [`NullSink`] observer leaves
//! results byte-identical to an unobserved run.

use anyhow::Result;

use crate::util::profile::Profiler;
use crate::util::trace::{now_us, Span, TraceId, Tracer};

/// One outer optimization step, as seen by an observer.
#[derive(Debug, Clone, Copy)]
pub struct StepEvent<'a> {
    /// Replication indices the snapshot covers (the live rows for a
    /// panel step; a single replication for a sequential driver).
    pub reps: &'a [usize],
    /// 1-based epoch / iteration just completed.
    pub epoch: usize,
    /// Total epochs / iterations the run was asked for.
    pub epochs: usize,
    /// Recorded value per covered replication (epoch objective for FW
    /// tasks, minibatch loss for SQN), aligned with `reps`.
    pub objs: &'a [f64],
    /// Replications still advancing after this step (always
    /// `reps.len()` unless a budget policy froze rows).
    pub live: usize,
    /// Wall-clock seconds of the step's timed region.
    pub step_s: f64,
    /// Per-phase attribution of THIS step (DESIGN.md §15) — already
    /// accumulated outside the timed region, so reading it here is free.
    pub profile: Profiler,
}

/// Per-step observer threaded through the drivers.  `Send` so the
/// native-parallel sequential arm can share one sink across its
/// replication threads (behind a mutex).
pub trait ProgressSink: Send {
    fn on_step(&mut self, ev: &StepEvent<'_>) -> Result<()>;
}

/// The no-op observer: drivers run exactly as they do unobserved.
#[derive(Debug, Default)]
pub struct NullSink;

impl ProgressSink for NullSink {
    fn on_step(&mut self, _ev: &StepEvent<'_>) -> Result<()> {
        Ok(())
    }
}

/// Observer that records one `epoch` [`Span`] per step event and passes
/// the event through to `inner` untouched — how `--trace-out` gets
/// per-epoch execution spans without a second hook into the drivers
/// (DESIGN.md §18).
///
/// Invariance: the span is derived from the event's already-measured
/// `step_s` (its start is back-computed from one clock read taken here,
/// after the timed region closed), so tracing a run cannot perturb it.
pub struct TracingSink<'a> {
    tracer: std::sync::Arc<Tracer>,
    trace: TraceId,
    inner: &'a mut dyn ProgressSink,
}

impl<'a> TracingSink<'a> {
    pub fn new(tracer: std::sync::Arc<Tracer>, trace: TraceId,
               inner: &'a mut dyn ProgressSink) -> TracingSink<'a> {
        TracingSink { tracer, trace, inner }
    }
}

impl ProgressSink for TracingSink<'_> {
    fn on_step(&mut self, ev: &StepEvent<'_>) -> Result<()> {
        let end_us = now_us();
        // truncation can only shrink the back-computed interval, so the
        // epoch span never starts before its enclosing execute span
        let start_us = end_us.saturating_sub((ev.step_s * 1e6) as u64);
        self.tracer.record(
            &Span::new(self.trace, "epoch", start_us, end_us)
                .with("epoch", ev.epoch)
                .with("epochs", ev.epochs)
                .with("live", ev.live));
        self.inner.on_step(ev)
    }
}

/// Adapter sharing ONE sink across the replication threads of the
/// native-parallel sequential arm: each thread locks per event, so
/// events from different replications interleave but never tear.
pub struct SharedSink<'a, 'b>(
    pub &'a std::sync::Mutex<&'b mut dyn ProgressSink>,
);

impl ProgressSink for SharedSink<'_, '_> {
    fn on_step(&mut self, ev: &StepEvent<'_>) -> Result<()> {
        self.0.lock().expect("progress sink poisoned").on_step(ev)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Sink that records (rep, epoch, obj) triples.
    #[derive(Default)]
    pub(crate) struct RecordingSink(pub Vec<(usize, usize, f64)>);

    impl ProgressSink for RecordingSink {
        fn on_step(&mut self, ev: &StepEvent<'_>) -> Result<()> {
            for (&r, &o) in ev.reps.iter().zip(ev.objs) {
                self.0.push((r, ev.epoch, o));
            }
            Ok(())
        }
    }

    #[test]
    fn null_sink_accepts_everything() {
        let ev = StepEvent {
            reps: &[0, 1],
            epoch: 1,
            epochs: 4,
            objs: &[0.5, 0.25],
            live: 2,
            step_s: 0.0,
            profile: Profiler::default(),
        };
        assert!(NullSink.on_step(&ev).is_ok());
    }

    #[test]
    fn tracing_sink_records_epoch_spans_and_passes_through() {
        use crate::util::json::Value;
        use crate::util::trace::SharedBuf;
        let buf = SharedBuf::default();
        let tracer =
            std::sync::Arc::new(Tracer::to_writer(Box::new(buf.clone())));
        let trace = TraceId::mint();
        let mut inner = RecordingSink::default();
        {
            let mut sink = TracingSink::new(tracer, trace, &mut inner);
            let ev = StepEvent {
                reps: &[0],
                epoch: 2,
                epochs: 4,
                objs: &[0.75],
                live: 1,
                step_s: 0.001,
                profile: Profiler::default(),
            };
            sink.on_step(&ev).unwrap();
        }
        // the event reached the inner sink untouched…
        assert_eq!(inner.0, vec![(0, 2, 0.75)]);
        // …and exactly one epoch span landed in the trace, carrying the
        // event's already-measured duration
        let text =
            String::from_utf8(buf.0.lock().unwrap().clone()).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 1);
        let v = Value::parse(lines[0]).unwrap();
        assert_eq!(v.get("name").and_then(Value::as_str), Some("epoch"));
        assert_eq!(v.get("dur").and_then(Value::as_f64), Some(1000.0));
        let args = v.get("args").unwrap();
        assert_eq!(args.get("trace").and_then(Value::as_str),
                   Some(trace.as_hex().as_str()));
        assert_eq!(args.get("epoch").and_then(Value::as_str), Some("2"));
    }

    #[test]
    fn shared_sink_serializes_onto_the_inner_sink() {
        let mut inner = RecordingSink::default();
        {
            let boxed: &mut dyn ProgressSink = &mut inner;
            let shared = std::sync::Mutex::new(boxed);
            let ev = StepEvent {
                reps: &[2],
                epoch: 3,
                epochs: 8,
                objs: &[1.5],
                live: 1,
                step_s: 0.0,
                profile: Profiler::default(),
            };
            SharedSink(&shared).on_step(&ev).unwrap();
        }
        assert_eq!(inner.0, vec![(2, 3, 1.5)]);
    }
}
