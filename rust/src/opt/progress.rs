//! The execution plane's observer hook (DESIGN.md §14).
//!
//! Streaming sessions need per-epoch objective snapshots out of the
//! drivers without the drivers learning anything about serving.  The
//! contract is one trait: a [`ProgressSink`] receives a [`StepEvent`]
//! after every outer step — from the generic replication-panel loop
//! (one event covering all live rows) and from the sequential drivers
//! (one event per replication per epoch).  The coordinator threads a
//! sink through `run_with`; the service's worker adapts it onto the
//! per-job reply channel; everything below the coordinator stays
//! serving-agnostic.
//!
//! Sink calls happen OUTSIDE the timed regions (after the step's
//! wall-clock has been recorded), so an attached observer never
//! perturbs the reported timings, and a [`NullSink`] observer leaves
//! results byte-identical to an unobserved run.

use anyhow::Result;

use crate::util::profile::Profiler;

/// One outer optimization step, as seen by an observer.
#[derive(Debug, Clone, Copy)]
pub struct StepEvent<'a> {
    /// Replication indices the snapshot covers (the live rows for a
    /// panel step; a single replication for a sequential driver).
    pub reps: &'a [usize],
    /// 1-based epoch / iteration just completed.
    pub epoch: usize,
    /// Total epochs / iterations the run was asked for.
    pub epochs: usize,
    /// Recorded value per covered replication (epoch objective for FW
    /// tasks, minibatch loss for SQN), aligned with `reps`.
    pub objs: &'a [f64],
    /// Replications still advancing after this step (always
    /// `reps.len()` unless a budget policy froze rows).
    pub live: usize,
    /// Wall-clock seconds of the step's timed region.
    pub step_s: f64,
    /// Per-phase attribution of THIS step (DESIGN.md §15) — already
    /// accumulated outside the timed region, so reading it here is free.
    pub profile: Profiler,
}

/// Per-step observer threaded through the drivers.  `Send` so the
/// native-parallel sequential arm can share one sink across its
/// replication threads (behind a mutex).
pub trait ProgressSink: Send {
    fn on_step(&mut self, ev: &StepEvent<'_>) -> Result<()>;
}

/// The no-op observer: drivers run exactly as they do unobserved.
#[derive(Debug, Default)]
pub struct NullSink;

impl ProgressSink for NullSink {
    fn on_step(&mut self, _ev: &StepEvent<'_>) -> Result<()> {
        Ok(())
    }
}

/// Adapter sharing ONE sink across the replication threads of the
/// native-parallel sequential arm: each thread locks per event, so
/// events from different replications interleave but never tear.
pub struct SharedSink<'a, 'b>(
    pub &'a std::sync::Mutex<&'b mut dyn ProgressSink>,
);

impl ProgressSink for SharedSink<'_, '_> {
    fn on_step(&mut self, ev: &StepEvent<'_>) -> Result<()> {
        self.0.lock().expect("progress sink poisoned").on_step(ev)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Sink that records (rep, epoch, obj) triples.
    #[derive(Default)]
    pub(crate) struct RecordingSink(pub Vec<(usize, usize, f64)>);

    impl ProgressSink for RecordingSink {
        fn on_step(&mut self, ev: &StepEvent<'_>) -> Result<()> {
            for (&r, &o) in ev.reps.iter().zip(ev.objs) {
                self.0.push((r, ev.epoch, o));
            }
            Ok(())
        }
    }

    #[test]
    fn null_sink_accepts_everything() {
        let ev = StepEvent {
            reps: &[0, 1],
            epoch: 1,
            epochs: 4,
            objs: &[0.5, 0.25],
            live: 2,
            step_s: 0.0,
            profile: Profiler::default(),
        };
        assert!(NullSink.on_step(&ev).is_ok());
    }

    #[test]
    fn shared_sink_serializes_onto_the_inner_sink() {
        let mut inner = RecordingSink::default();
        {
            let boxed: &mut dyn ProgressSink = &mut inner;
            let shared = std::sync::Mutex::new(boxed);
            let ev = StepEvent {
                reps: &[2],
                epoch: 3,
                epochs: 8,
                objs: &[1.5],
                live: 1,
                step_s: 0.0,
                profile: Profiler::default(),
            };
            SharedSink(&shared).on_step(&ev).unwrap();
        }
        assert_eq!(inner.0, vec![(2, 3, 1.5)]);
    }
}
