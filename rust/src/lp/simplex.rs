//! Two-phase primal simplex on a dense tableau.
//!
//! Solves  min cᵀx  s.t.  Ax ≤ b, x ≥ 0  (b of any sign).
//!
//! * Rows with negative right-hand side are negated (their slack becomes a
//!   surplus) and receive an artificial variable; phase 1 minimizes the sum
//!   of artificials to find a basic feasible solution.
//! * Phase 2 optimizes the real objective from that basis.
//! * Pivot selection uses Dantzig's rule with a Bland fallback after a pivot
//!   budget, guaranteeing termination on degenerate instances.
//!
//! Internals run in f64 regardless of the caller's precision; the
//! Frank-Wolfe driver feeds f32 gradients and reads back f32 vertices.

const EPS: f64 = 1e-9;

/// Problem statement: minimize `c·x` subject to `a x ≤ b`, `x ≥ 0`.
#[derive(Debug, Clone)]
pub struct LpProblem {
    pub c: Vec<f64>,
    /// Row-major m×n constraint matrix.
    pub a: Vec<f64>,
    pub b: Vec<f64>,
    pub m: usize,
    pub n: usize,
}

impl LpProblem {
    pub fn new(c: Vec<f64>, a: Vec<f64>, b: Vec<f64>) -> Self {
        let n = c.len();
        let m = b.len();
        assert_eq!(a.len(), m * n, "A must be m×n row-major");
        LpProblem { c, a, b, m, n }
    }

    pub fn from_f32(c: &[f32], a: &[f32], b: &[f32]) -> Self {
        Self::new(
            c.iter().map(|&v| v as f64).collect(),
            a.iter().map(|&v| v as f64).collect(),
            b.iter().map(|&v| v as f64).collect(),
        )
    }
}

#[derive(Debug, Clone, PartialEq)]
pub enum LpResult {
    Optimal {
        x: Vec<f64>,
        obj: f64,
        /// Objective-row values at the slack columns at optimality
        /// (σᵢ ≥ 0); the LP dual prices are yᵢ = −σᵢ.  Used by the
        /// column-generation LMO to price external columns:
        /// r_j = c_j + Σᵢ σᵢ aᵢⱼ.
        duals: Vec<f64>,
    },
    Unbounded,
    Infeasible,
}

impl LpResult {
    pub fn x(&self) -> Option<&[f64]> {
        match self {
            LpResult::Optimal { x, .. } => Some(x),
            _ => None,
        }
    }

    pub fn duals(&self) -> Option<&[f64]> {
        match self {
            LpResult::Optimal { duals, .. } => Some(duals),
            _ => None,
        }
    }

    pub fn obj(&self) -> Option<f64> {
        match self {
            LpResult::Optimal { obj, .. } => Some(*obj),
            _ => None,
        }
    }
}

struct Tableau {
    /// (m+1) × (cols+1); last row = objective, last col = RHS.
    t: Vec<f64>,
    m: usize,
    cols: usize,
    basis: Vec<usize>,
}

impl Tableau {
    #[inline]
    fn at(&self, r: usize, c: usize) -> f64 {
        self.t[r * (self.cols + 1) + c]
    }

    #[inline]
    fn at_mut(&mut self, r: usize, c: usize) -> &mut f64 {
        &mut self.t[r * (self.cols + 1) + c]
    }

    fn rhs(&self, r: usize) -> f64 {
        self.at(r, self.cols)
    }

    fn pivot(&mut self, pr: usize, pc: usize) {
        let w = self.cols + 1;
        let piv = self.at(pr, pc);
        debug_assert!(piv.abs() > EPS);
        let inv = 1.0 / piv;
        for c in 0..w {
            self.t[pr * w + c] *= inv;
        }
        for r in 0..=self.m {
            if r == pr {
                continue;
            }
            let factor = self.at(r, pc);
            if factor.abs() <= EPS {
                continue;
            }
            for c in 0..w {
                let v = self.t[pr * w + c];
                self.t[r * w + c] -= factor * v;
            }
        }
        self.basis[pr] = pc;
    }

    /// One simplex phase: returns false if unbounded.
    /// `allowed` restricts entering columns (used to bar artificials in
    /// phase 2).
    fn optimize(&mut self, allowed: &dyn Fn(usize) -> bool) -> bool {
        // Dantzig until the budget, then Bland (guaranteed finite).
        let budget = 50 * (self.m + self.cols);
        let mut iters = 0usize;
        loop {
            iters += 1;
            let bland = iters > budget;
            // entering column: objective row coefficient < -EPS
            let mut enter: Option<usize> = None;
            let mut best = -EPS;
            for c in 0..self.cols {
                if !allowed(c) {
                    continue;
                }
                let red = self.at(self.m, c);
                if bland {
                    if red < -EPS {
                        enter = Some(c);
                        break;
                    }
                } else if red < best {
                    best = red;
                    enter = Some(c);
                }
            }
            let Some(pc) = enter else { return true };
            // leaving row: min ratio test (Bland tie-break on basis index)
            let mut leave: Option<usize> = None;
            let mut best_ratio = f64::INFINITY;
            for r in 0..self.m {
                let a = self.at(r, pc);
                if a > EPS {
                    let ratio = self.rhs(r) / a;
                    let better = ratio < best_ratio - EPS
                        || (ratio < best_ratio + EPS
                            && leave.map(|l| self.basis[r] < self.basis[l]).unwrap_or(false));
                    if better {
                        best_ratio = ratio;
                        leave = Some(r);
                    }
                }
            }
            let Some(pr) = leave else { return false };
            self.pivot(pr, pc);
        }
    }
}

/// Reusable buffers for [`solve_into`] — sized on first use,
/// allocation-free on every later solve of the same (or smaller) shape.
/// Every buffer is fully re-initialized per call (`clear` + `resize` /
/// `extend`), so a reused workspace is bitwise-identical to a fresh one.
///
/// The tableau-side fields are `pub(crate)` so the panel layer
/// (`lp::panel`) can seed a row workspace from a shared post-phase-1
/// tableau without re-running the c-independent work per row.
#[derive(Debug, Default)]
pub struct Workspace {
    a: Vec<f64>,
    b: Vec<f64>,
    pub(crate) slack_sign: Vec<f64>,
    needs_art: Vec<bool>,
    pub(crate) t: Vec<f64>,
    pub(crate) basis: Vec<usize>,
    /// Primal solution after an `Optimal` return.
    pub x: Vec<f64>,
    /// Objective-row slack values (σᵢ, sign-corrected) after an `Optimal`
    /// return — see [`LpResult::Optimal::duals`].
    pub duals: Vec<f64>,
}

/// Outcome of [`solve_into`]; the primal/dual vectors stay in the
/// [`Workspace`] so the hot path never allocates a result.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LpStatus {
    Optimal { obj: f64 },
    Unbounded,
    Infeasible,
}

/// Solve the LP.  See module docs for the algorithm.
pub fn solve(p: &LpProblem) -> LpResult {
    let mut ws = Workspace::default();
    match solve_into(&p.c, &p.a, &p.b, p.m, p.n, &mut ws) {
        LpStatus::Optimal { obj } => LpResult::Optimal {
            x: std::mem::take(&mut ws.x),
            obj,
            duals: std::mem::take(&mut ws.duals),
        },
        LpStatus::Unbounded => LpResult::Unbounded,
        LpStatus::Infeasible => LpResult::Infeasible,
    }
}

/// Whether the c-independent seed build found a basic feasible solution.
/// On `Infeasible` the phase-1 tableau stays in the workspace, exactly as
/// [`solve_into`] leaves it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub(crate) enum SeedStatus {
    Feasible,
    Infeasible,
}

/// The c-independent half of [`solve_into`]: normalize rows to `b ≥ 0`,
/// build the initial tableau, and (when artificials are needed) run
/// phase 1 and drive residual artificials out of the basis.  None of this
/// arithmetic reads the objective row, so the resulting tableau + basis
/// ("the seed") is shared by EVERY objective over the same `(A, b)` —
/// the fact the panel LMO layer (`lp::panel`) exploits to factor the
/// shared constraint matrix once per step instead of once per row.
///
/// On return `ws.t` / `ws.basis` hold the post-phase-1 tableau and
/// `ws.slack_sign` the row-negation signs; the column count is returned
/// so phase 2 can address the tableau.  Requires `m > 0` (the caller
/// handles the constraint-free shape).
pub(crate) fn build_seed(a_in: &[f64], b_in: &[f64], m: usize, n: usize,
                         ws: &mut Workspace) -> (usize, SeedStatus) {
    debug_assert!(m > 0);
    // Normalize rows to b ≥ 0 and track which need artificials.
    ws.a.clear();
    ws.a.extend_from_slice(a_in);
    ws.b.clear();
    ws.b.extend_from_slice(b_in);
    let a = &mut ws.a;
    let b = &mut ws.b;
    ws.slack_sign.clear();
    ws.slack_sign.resize(m, 1.0);
    let slack_sign = &mut ws.slack_sign;
    for r in 0..m {
        if b[r] < 0.0 {
            b[r] = -b[r];
            for c in 0..n {
                a[r * n + c] = -a[r * n + c];
            }
            slack_sign[r] = -1.0; // slack col becomes -1 ⇒ artificial needed
        }
    }
    ws.needs_art.clear();
    ws.needs_art.extend(slack_sign.iter().map(|&s| s < 0.0));
    let needs_art = &ws.needs_art;
    let n_art = needs_art.iter().filter(|&&x| x).count();
    let cols = n + m + n_art;
    let w = cols + 1;
    let mut t = std::mem::take(&mut ws.t);
    t.clear();
    t.resize((m + 1) * w, 0.0);

    // Constraint rows.
    let mut art_col = n + m;
    let mut basis = std::mem::take(&mut ws.basis);
    basis.clear();
    basis.resize(m, 0);
    for r in 0..m {
        for c in 0..n {
            t[r * w + c] = a[r * n + c];
        }
        t[r * w + n + r] = slack_sign[r]; // slack (or surplus)
        if needs_art[r] {
            t[r * w + art_col] = 1.0;
            basis[r] = art_col;
            art_col += 1;
        } else {
            basis[r] = n + r;
        }
        t[r * w + cols] = b[r];
    }

    let mut tab = Tableau { t, m, cols, basis };

    // ---- Phase 1 ----------------------------------------------------------
    if n_art > 0 {
        // objective: minimize sum of artificials; price out basic artificials
        for c in n + m..cols {
            *tab.at_mut(m, c) = 1.0;
        }
        for r in 0..m {
            if needs_art[r] {
                let w1 = tab.cols + 1;
                for c in 0..w1 {
                    let v = tab.t[r * w1 + c];
                    tab.t[m * w1 + c] -= v;
                }
            }
        }
        let bounded = tab.optimize(&|_| true);
        debug_assert!(bounded, "phase 1 is bounded below by 0");
        let phase1_obj = -tab.rhs(m);
        if phase1_obj > 1e-7 {
            ws.t = tab.t;
            ws.basis = tab.basis;
            return (cols, SeedStatus::Infeasible);
        }
        // Drive any residual artificial out of the basis.
        for r in 0..m {
            if tab.basis[r] >= n + m {
                let mut swapped = false;
                for c in 0..n + m {
                    if tab.at(r, c).abs() > EPS {
                        tab.pivot(r, c);
                        swapped = true;
                        break;
                    }
                }
                if !swapped {
                    // Redundant row: keep the (zero-valued) artificial basic;
                    // it can never re-enter (barred in phase 2).
                }
            }
        }
    }
    ws.t = tab.t;
    ws.basis = tab.basis;
    (cols, SeedStatus::Feasible)
}

/// The c-dependent half of [`solve_into`]: phase 2 over a seed tableau
/// left by [`build_seed`] (in `t`/`basis`, with `cols` columns and the
/// row-negation signs in `slack_sign`).  Consumes the tableau in place —
/// callers that reuse a seed for many objectives (the panel layer) must
/// hand a COPY per row.  The primal vertex and sign-corrected duals land
/// in `x`/`duals` exactly as `solve_into` leaves them.
pub(crate) fn phase2(c_in: &[f64], m: usize, n: usize, cols: usize,
                     slack_sign: &[f64], t: &mut Vec<f64>,
                     basis: &mut Vec<usize>, x: &mut Vec<f64>,
                     duals: &mut Vec<f64>) -> LpStatus {
    let mut tab = Tableau {
        t: std::mem::take(t),
        m,
        cols,
        basis: std::mem::take(basis),
    };
    // Reset objective row to the real costs, then price out basic variables.
    {
        let w2 = tab.cols + 1;
        for c in 0..w2 {
            tab.t[m * w2 + c] = 0.0;
        }
        for c in 0..n {
            tab.t[m * w2 + c] = c_in[c];
        }
        for r in 0..m {
            let bc = tab.basis[r];
            let coef = tab.t[m * w2 + bc];
            if coef.abs() > EPS {
                for c in 0..w2 {
                    let v = tab.t[r * w2 + c];
                    tab.t[m * w2 + c] -= coef * v;
                }
            }
        }
    }
    let bounded = tab.optimize(&|c| c < n + m); // artificials barred
    if !bounded {
        *t = tab.t;
        *basis = tab.basis;
        return LpStatus::Unbounded;
    }

    x.clear();
    x.resize(n, 0.0);
    for r in 0..m {
        if tab.basis[r] < n {
            x[tab.basis[r]] = tab.rhs(r).max(0.0);
        }
    }
    let obj = c_in.iter().zip(x.iter()).map(|(c, v)| c * v).sum();
    // σᵢ: objective-row entries at the slack columns.  Rows that were
    // negated for phase 1 flip the slack sign, so un-flip here.
    duals.clear();
    duals.extend((0..m).map(|i| tab.at(m, n + i) * slack_sign[i]));
    *t = tab.t;
    *basis = tab.basis;
    LpStatus::Optimal { obj }
}

/// Arena variant of [`solve`]: minimize `c·x` s.t. `a x ≤ b`, `x ≥ 0`,
/// with every intermediate living in `ws`.  Identical arithmetic to
/// [`solve`] — only the storage is caller-owned.  Internally this is the
/// composition [`build_seed`] (c-independent: normalization + tableau +
/// phase 1) then [`phase2`] (the objective-dependent pivots), which is
/// what lets the panel layer share one seed across all R objective rows
/// while staying bitwise-equal to this sequential path by construction.
pub fn solve_into(c_in: &[f64], a_in: &[f64], b_in: &[f64], m: usize,
                  n: usize, ws: &mut Workspace) -> LpStatus {
    assert_eq!(c_in.len(), n);
    assert_eq!(b_in.len(), m);
    assert_eq!(a_in.len(), m * n, "A must be m×n row-major");
    if m == 0 {
        // Only x ≥ 0: bounded iff c ≥ 0, optimum at the origin.
        return if c_in.iter().all(|&ci| ci >= -EPS) {
            ws.x.clear();
            ws.x.resize(n, 0.0);
            ws.duals.clear();
            LpStatus::Optimal { obj: 0.0 }
        } else {
            LpStatus::Unbounded
        };
    }
    let (cols, status) = build_seed(a_in, b_in, m, n, ws);
    if status == SeedStatus::Infeasible {
        return LpStatus::Infeasible;
    }
    phase2(c_in, m, n, cols, &ws.slack_sign, &mut ws.t, &mut ws.basis,
           &mut ws.x, &mut ws.duals)
}

/// Feasibility check used by tests and the FW driver's debug assertions.
pub fn is_feasible(p: &LpProblem, x: &[f64], tol: f64) -> bool {
    if x.iter().any(|&v| v < -tol) {
        return false;
    }
    for r in 0..p.m {
        let lhs: f64 = (0..p.n).map(|c| p.a[r * p.n + c] * x[c]).sum();
        if lhs > p.b[r] + tol {
            return false;
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_opt(res: &LpResult, want_x: &[f64], want_obj: f64) {
        match res {
            LpResult::Optimal { x, obj, .. } => {
                assert!((obj - want_obj).abs() < 1e-6, "obj {} want {}", obj, want_obj);
                for (a, b) in x.iter().zip(want_x) {
                    assert!((a - b).abs() < 1e-6, "x {:?} want {:?}", x, want_x);
                }
            }
            other => panic!("expected optimal, got {:?}", other),
        }
    }

    #[test]
    fn textbook_max_problem() {
        // max 3x + 5y s.t. x ≤ 4, 2y ≤ 12, 3x + 2y ≤ 18  → (2, 6), 36
        let p = LpProblem::new(
            vec![-3.0, -5.0],
            vec![1.0, 0.0, 0.0, 2.0, 3.0, 2.0],
            vec![4.0, 12.0, 18.0],
        );
        assert_opt(&solve(&p), &[2.0, 6.0], -36.0);
    }

    #[test]
    fn origin_optimal_when_costs_positive() {
        let p = LpProblem::new(vec![1.0, 2.0], vec![1.0, 1.0], vec![10.0]);
        assert_opt(&solve(&p), &[0.0, 0.0], 0.0);
    }

    #[test]
    fn unbounded_detected() {
        // min -x with only y constrained
        let p = LpProblem::new(vec![-1.0, 0.0], vec![0.0, 1.0], vec![5.0]);
        assert_eq!(solve(&p), LpResult::Unbounded);
    }

    #[test]
    fn unbounded_no_constraints() {
        let p = LpProblem::new(vec![-1.0], vec![], vec![]);
        assert_eq!(solve(&p), LpResult::Unbounded);
        let p2 = LpProblem::new(vec![1.0], vec![], vec![]);
        assert_opt(&solve(&p2), &[0.0], 0.0);
    }

    #[test]
    fn infeasible_detected() {
        // x ≤ -1 with x ≥ 0 is empty
        let p = LpProblem::new(vec![1.0], vec![1.0], vec![-1.0]);
        assert_eq!(solve(&p), LpResult::Infeasible);
    }

    #[test]
    fn negative_rhs_feasible_via_phase1() {
        // -x ≤ -2 ⇔ x ≥ 2; min x → x = 2
        let p = LpProblem::new(vec![1.0], vec![-1.0], vec![-2.0]);
        assert_opt(&solve(&p), &[2.0], 2.0);
    }

    #[test]
    fn equality_via_pair_of_inequalities() {
        // x + y ≤ 5 and -(x+y) ≤ -5 ⇒ x + y = 5; min 2x + y → (0,5)
        let p = LpProblem::new(
            vec![2.0, 1.0],
            vec![1.0, 1.0, -1.0, -1.0],
            vec![5.0, -5.0],
        );
        assert_opt(&solve(&p), &[0.0, 5.0], 5.0);
    }

    #[test]
    fn degenerate_cycling_guard() {
        // Classic Beale cycling example (degenerate); Bland fallback must
        // terminate with the optimum -0.05.
        let p = LpProblem::new(
            vec![-0.75, 150.0, -0.02, 6.0],
            vec![
                0.25, -60.0, -0.04, 9.0,
                0.5, -90.0, -0.02, 3.0,
                0.0, 0.0, 1.0, 0.0,
            ],
            vec![0.0, 0.0, 1.0],
        );
        match solve(&p) {
            LpResult::Optimal { obj, .. } => assert!((obj + 0.05).abs() < 1e-6, "obj {}", obj),
            other => panic!("expected optimal, got {:?}", other),
        }
    }

    #[test]
    fn simplex_lmo_shape() {
        // FW subproblem over a capped simplex: min g·s, s ≥ 0, Σs ≤ 1 —
        // LP answer must equal the analytic vertex rule.
        let g = [0.3f64, -2.0, 0.7];
        let p = LpProblem::new(g.to_vec(), vec![1.0, 1.0, 1.0], vec![1.0]);
        assert_opt(&solve(&p), &[0.0, 1.0, 0.0], -2.0);
        // all-positive gradient → origin
        let p2 = LpProblem::new(vec![0.3, 2.0, 0.7], vec![1.0, 1.0, 1.0], vec![1.0]);
        assert_opt(&solve(&p2), &[0.0, 0.0, 0.0], 0.0);
    }

    #[test]
    fn redundant_constraints_ok() {
        // Duplicate rows should not confuse the basis bookkeeping.
        let p = LpProblem::new(
            vec![-1.0, -1.0],
            vec![1.0, 1.0, 1.0, 1.0, 1.0, 0.0],
            vec![4.0, 4.0, 3.0],
        );
        match solve(&p) {
            LpResult::Optimal { obj, x, .. } => {
                assert!((obj + 4.0).abs() < 1e-6);
                assert!(is_feasible(&p, &x, 1e-7));
            }
            other => panic!("{:?}", other),
        }
    }

    #[test]
    fn reused_workspace_is_bitwise_fresh_solve() {
        // One workspace driven through problems of different shapes and
        // outcomes must reproduce the allocating solver exactly, bit for
        // bit — the arena path re-initializes every buffer per call.
        let problems = [
            LpProblem::new(vec![-3.0, -5.0],
                           vec![1.0, 0.0, 0.0, 2.0, 3.0, 2.0],
                           vec![4.0, 12.0, 18.0]),
            LpProblem::new(vec![1.0], vec![1.0], vec![-1.0]), // infeasible
            LpProblem::new(vec![1.0], vec![-1.0], vec![-2.0]), // phase 1
            LpProblem::new(vec![-1.0, 0.0], vec![0.0, 1.0], vec![5.0]),
            LpProblem::new(vec![2.0, 1.0],
                           vec![1.0, 1.0, -1.0, -1.0],
                           vec![5.0, -5.0]),
        ];
        let mut ws = Workspace::default();
        for p in &problems {
            let want = solve(p);
            let status = solve_into(&p.c, &p.a, &p.b, p.m, p.n, &mut ws);
            match (want, status) {
                (LpResult::Optimal { x, obj, duals },
                 LpStatus::Optimal { obj: obj2 }) => {
                    assert_eq!(obj.to_bits(), obj2.to_bits());
                    assert_eq!(x.len(), ws.x.len());
                    for (a, b) in x.iter().zip(&ws.x) {
                        assert_eq!(a.to_bits(), b.to_bits());
                    }
                    for (a, b) in duals.iter().zip(&ws.duals) {
                        assert_eq!(a.to_bits(), b.to_bits());
                    }
                }
                (LpResult::Unbounded, LpStatus::Unbounded) => {}
                (LpResult::Infeasible, LpStatus::Infeasible) => {}
                (w, g) => panic!("solve {:?} vs solve_into {:?}", w, g),
            }
        }
    }

    #[test]
    fn solution_always_feasible() {
        // Random small instances: whatever the optimum, it must be feasible
        // and no worse than any sampled feasible point.
        use crate::rng::Philox;
        let mut rng = Philox::new(17);
        for case in 0..50 {
            let n = 2 + (case % 3);
            let m = 1 + (case % 4);
            let c: Vec<f64> = (0..n).map(|_| rng.uniform_f32(-2.0, 2.0) as f64).collect();
            let a: Vec<f64> = (0..m * n).map(|_| rng.uniform_f32(0.1, 1.5) as f64).collect();
            let b: Vec<f64> = (0..m).map(|_| rng.uniform_f32(0.5, 4.0) as f64).collect();
            let p = LpProblem::new(c.clone(), a, b);
            match solve(&p) {
                LpResult::Optimal { x, obj, .. } => {
                    assert!(is_feasible(&p, &x, 1e-6), "case {}", case);
                    // compare against random feasible points (scaled corners)
                    for trial in 0..20 {
                        let mut y = vec![0.0f64; n];
                        for v in y.iter_mut() {
                            *v = rng.next_f64() * 2.0;
                        }
                        // scale into feasibility
                        let mut worst = 1.0f64;
                        for r in 0..p.m {
                            let lhs: f64 = (0..n).map(|j| p.a[r * n + j] * y[j]).sum();
                            if lhs > p.b[r] {
                                worst = worst.min(p.b[r] / lhs);
                            }
                        }
                        for v in y.iter_mut() {
                            *v *= worst;
                        }
                        let oy: f64 = c.iter().zip(&y).map(|(ci, yi)| ci * yi).sum();
                        assert!(obj <= oy + 1e-6, "case {} trial {}: {} > {}", case, trial, obj, oy);
                    }
                }
                // positive technology matrix + positive capacity is always
                // feasible (origin) and bounded
                other => panic!("case {}: unexpected {:?}", case, other),
            }
        }
    }
}
