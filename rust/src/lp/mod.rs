//! Dense linear programming for the Frank-Wolfe linear subproblem of the
//! constrained newsvendor task (Algorithm 2 line 8):
//! `min c·x  s.t.  A x <= b, x >= 0`.
//!
//! The paper's JAX implementation leans on an off-the-shelf LP routine for
//! this; offline we build the substrate ourselves: a two-phase primal
//! simplex on a dense tableau with Bland's anti-cycling rule
//! ([`simplex::solve`]).

pub mod panel;
pub mod simplex;

pub use panel::PanelWorkspace;
pub use simplex::{is_feasible, solve, solve_into, LpProblem, LpResult,
                  LpStatus, Workspace};
