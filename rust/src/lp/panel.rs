//! Panel LMO batch core (DESIGN.md §17): advance R per-replication LPs
//! that share ONE constraint system `{Ax ≤ b, x ≥ 0}` together instead of
//! serially, exploiting that only the objective row differs per
//! replication.
//!
//! The two-phase simplex splits cleanly along that axis
//! (`simplex::build_seed` / `simplex::phase2`): row normalization, the
//! initial tableau, phase 1, and the artificial drive-out never read the
//! objective, so their result — "the seed" — is computed ONCE per shared
//! `(A, b)` and cached in a [`PanelWorkspace`] across steps (warm-start:
//! re-`ensure_seed` calls with unchanged data are O(m·n) compares, no
//! pivots).  Each row then copies the seed tableau into its own
//! [`Workspace`] arena and runs phase 2 alone — the EXACT state
//! `lp::solve_into` reaches before its phase 2, so every row's pivot
//! sequence, vertex, objective, and duals are bitwise-identical to the
//! sequential solver by construction (pinned by the property tests
//! below and `tests/batch_determinism.rs`).
//!
//! Row fan-out rides the PR 8 idiom: `pool::chunk_len` +
//! `pool::parallel_try_jobs` over disjoint `&mut` workspace/status
//! chunks, so `threads > 1` parallelizes the per-row phase-2 wall while
//! `threads == 1` runs the single chunk inline with zero heap traffic
//! (pinned by `tests/alloc_regression.rs`).

use super::simplex::{self, LpStatus, SeedStatus, Workspace};
use crate::util::pool;

/// Cached c-independent simplex state for one shared `(A, b)`: the
/// post-phase-1 tableau, basis, and row signs, plus a copy of the inputs
/// so reuse can be verified instead of trusted.  Build once via
/// [`PanelWorkspace::ensure_seed`], then solve any number of objective
/// rows against it with [`PanelWorkspace::solve_row`] (`&self` — safe to
/// share across pool workers).
#[derive(Debug, Default)]
pub struct PanelWorkspace {
    m: usize,
    n: usize,
    cols: usize,
    /// The `(A, b)` the cached seed was built from, kept to make
    /// `ensure_seed` self-validating (bitwise compare, no allocation).
    a: Vec<f64>,
    b: Vec<f64>,
    /// Seed tableau / basis / slack signs live in a plain [`Workspace`]
    /// so the build path is literally `simplex::build_seed`.
    seed: Workspace,
    feasible: bool,
    ready: bool,
}

impl PanelWorkspace {
    pub fn new() -> Self {
        Self::default()
    }

    /// True once a seed is cached (after the first [`Self::ensure_seed`]).
    pub fn is_ready(&self) -> bool {
        self.ready
    }

    /// Build the shared seed for `(a, b)` — or, when the cached seed was
    /// built for bitwise-identical inputs, reuse it untouched (the
    /// warm-start across steps).  Returns `true` when a build ran.
    pub fn ensure_seed(&mut self, a: &[f64], b: &[f64], m: usize, n: usize)
        -> bool {
        assert_eq!(b.len(), m);
        assert_eq!(a.len(), m * n, "A must be m×n row-major");
        if self.ready && self.m == m && self.n == n && self.a == a
            && self.b == b {
            return false;
        }
        self.m = m;
        self.n = n;
        self.a.clear();
        self.a.extend_from_slice(a);
        self.b.clear();
        self.b.extend_from_slice(b);
        if m == 0 {
            // Constraint-free shape: no tableau exists; solve_row mirrors
            // solve_into's origin/unbounded early return per objective.
            self.cols = 0;
            self.feasible = true;
        } else {
            let (cols, status) = simplex::build_seed(a, b, m, n,
                                                     &mut self.seed);
            self.cols = cols;
            self.feasible = status == SeedStatus::Feasible;
        }
        self.ready = true;
        true
    }

    /// Solve `min c·x  s.t.  A x ≤ b, x ≥ 0` for ONE objective row from
    /// the cached seed, with every intermediate in the caller's `row`
    /// arena.  Bitwise-identical to `lp::solve_into(c, a, b, m, n, row)`:
    /// the seed copy reproduces the exact pre-phase-2 tableau the
    /// sequential path reaches, and phase 2 is the same code.  `&self`,
    /// so disjoint rows solve concurrently against one shared seed.
    pub fn solve_row(&self, c: &[f64], row: &mut Workspace) -> LpStatus {
        assert!(self.ready, "ensure_seed must run before solve_row");
        assert_eq!(c.len(), self.n);
        if self.m == 0 {
            const EPS: f64 = 1e-9;
            return if c.iter().all(|&ci| ci >= -EPS) {
                row.x.clear();
                row.x.resize(self.n, 0.0);
                row.duals.clear();
                LpStatus::Optimal { obj: 0.0 }
            } else {
                LpStatus::Unbounded
            };
        }
        if !self.feasible {
            return LpStatus::Infeasible;
        }
        // copy-on-read of the shared seed: phase 2 pivots in place, so
        // each row works on its own tableau (arena-backed — after the
        // first solve of this shape the copy is allocation-free)
        row.t.clear();
        row.t.extend_from_slice(&self.seed.t);
        row.basis.clear();
        row.basis.extend_from_slice(&self.seed.basis);
        simplex::phase2(c, self.m, self.n, self.cols,
                        &self.seed.slack_sign, &mut row.t, &mut row.basis,
                        &mut row.x, &mut row.duals)
    }

    /// Solve all R rows of the `[R × n]` objective panel `c` against the
    /// cached seed, fanning the rows out over `threads` pool workers with
    /// disjoint `&mut` chunks of `rows`/`statuses` (the PR 8 idiom —
    /// one chunk at `threads == 1` runs inline and allocation-free).
    /// `rows[i]` receives row i's vertex/duals, `statuses[i]` its status.
    pub fn solve_rows(&self, c: &[f64], rows: &mut [Workspace],
                      statuses: &mut [LpStatus], threads: usize) {
        let r = rows.len();
        assert_eq!(statuses.len(), r);
        assert_eq!(c.len(), r * self.n, "objective panel must be R×n");
        if r == 0 {
            return;
        }
        let n = self.n;
        let chunk = pool::chunk_len(r, threads);
        let jobs = rows
            .chunks_mut(chunk)
            .zip(statuses.chunks_mut(chunk))
            .zip(c.chunks(chunk * n))
            .map(|((row_chunk, status_chunk), c_chunk)| {
                move || {
                    for ((row, status), ci) in row_chunk
                        .iter_mut()
                        .zip(status_chunk.iter_mut())
                        .zip(c_chunk.chunks(n))
                    {
                        *status = self.solve_row(ci, row);
                    }
                    Ok(())
                }
            });
        // phase 2 cannot fail, so the Result plumbing is vestigial here;
        // the pool helper is shared with fallible batch engines
        pool::parallel_try_jobs(jobs).expect("panel rows are infallible");
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lp::{solve_into, LpProblem};
    use crate::rng::Philox;

    fn assert_bitwise(label: &str, want: LpStatus, want_ws: &Workspace,
                      got: LpStatus, got_ws: &Workspace) {
        match (want, got) {
            (LpStatus::Optimal { obj: a }, LpStatus::Optimal { obj: b }) => {
                assert_eq!(a.to_bits(), b.to_bits(), "{}: obj", label);
                assert_eq!(want_ws.x.len(), got_ws.x.len(), "{}", label);
                for (a, b) in want_ws.x.iter().zip(&got_ws.x) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{}: x", label);
                }
                assert_eq!(want_ws.duals.len(), got_ws.duals.len(),
                           "{}", label);
                for (a, b) in want_ws.duals.iter().zip(&got_ws.duals) {
                    assert_eq!(a.to_bits(), b.to_bits(), "{}: duals",
                               label);
                }
            }
            (a, b) => assert_eq!(a, b, "{}: status", label),
        }
    }

    #[test]
    fn seed_rows_are_bitwise_sequential_solves() {
        // Random shared (A, b) × many objective rows: solve_row from one
        // seed must reproduce solve_into per row, bit for bit — vertex,
        // objective, AND duals.
        let mut rng = Philox::new(0x9A41);
        for case in 0..30 {
            let n = 2 + (case % 5);
            let m = 1 + (case % 3);
            let a: Vec<f64> = (0..m * n)
                .map(|_| rng.uniform_f32(0.1, 1.5) as f64)
                .collect();
            let b: Vec<f64> =
                (0..m).map(|_| rng.uniform_f32(0.5, 4.0) as f64).collect();
            let mut panel = PanelWorkspace::new();
            assert!(panel.ensure_seed(&a, &b, m, n));
            assert!(!panel.ensure_seed(&a, &b, m, n), "warm reuse");
            let mut row = Workspace::default();
            let mut seq = Workspace::default();
            for _ in 0..8 {
                let c: Vec<f64> = (0..n)
                    .map(|_| rng.uniform_f32(-2.0, 2.0) as f64)
                    .collect();
                let want = solve_into(&c, &a, &b, m, n, &mut seq);
                let got = panel.solve_row(&c, &mut row);
                assert_bitwise(&format!("case {}", case), want, &seq, got,
                               &row);
            }
        }
    }

    #[test]
    fn seed_covers_phase1_and_degenerate_shapes() {
        // The seed path must agree with solve_into on every outcome class:
        // phase-1 instances (negative b), infeasible systems, unbounded
        // objectives, equality-via-pair rows, and m == 0.
        let problems = [
            LpProblem::new(vec![1.0], vec![-1.0], vec![-2.0]), // phase 1
            LpProblem::new(vec![1.0], vec![1.0], vec![-1.0]),  // infeasible
            LpProblem::new(vec![-1.0, 0.0], vec![0.0, 1.0], vec![5.0]),
            LpProblem::new(vec![2.0, 1.0],
                           vec![1.0, 1.0, -1.0, -1.0],
                           vec![5.0, -5.0]),
            LpProblem::new(vec![-1.0], vec![], vec![]), // m == 0 unbounded
            LpProblem::new(vec![1.0], vec![], vec![]),  // m == 0 origin
        ];
        for (i, p) in problems.iter().enumerate() {
            let mut panel = PanelWorkspace::new();
            panel.ensure_seed(&p.a, &p.b, p.m, p.n);
            let mut row = Workspace::default();
            let mut seq = Workspace::default();
            let want = solve_into(&p.c, &p.a, &p.b, p.m, p.n, &mut seq);
            let got = panel.solve_row(&p.c, &mut row);
            assert_bitwise(&format!("problem {}", i), want, &seq, got,
                           &row);
        }
    }

    #[test]
    fn ensure_seed_rebuilds_on_changed_inputs() {
        let mut panel = PanelWorkspace::new();
        assert!(panel.ensure_seed(&[1.0, 1.0], &[2.0], 1, 2));
        assert!(!panel.ensure_seed(&[1.0, 1.0], &[2.0], 1, 2));
        // changed b ⇒ rebuild; the stale seed must not leak through
        assert!(panel.ensure_seed(&[1.0, 1.0], &[3.0], 1, 2));
        let mut row = Workspace::default();
        let mut seq = Workspace::default();
        let c = [-1.0f64, -0.5];
        let want = solve_into(&c, &[1.0, 1.0], &[3.0], 1, 2, &mut seq);
        let got = panel.solve_row(&c, &mut row);
        assert_bitwise("rebuilt", want, &seq, got, &row);
    }

    #[test]
    fn solve_rows_matches_solve_row_for_every_thread_count() {
        // The fan-out wrapper is pure plumbing: any thread count must
        // produce the identical bits the inline path does, chunk
        // boundaries included (R=5 exercises uneven splits).
        let (m, n, r) = (2usize, 4usize, 5usize);
        let mut rng = Philox::new(0xF00);
        let a: Vec<f64> =
            (0..m * n).map(|_| rng.uniform_f32(0.1, 1.5) as f64).collect();
        let b: Vec<f64> =
            (0..m).map(|_| rng.uniform_f32(0.5, 4.0) as f64).collect();
        let c: Vec<f64> =
            (0..r * n).map(|_| rng.uniform_f32(-2.0, 2.0) as f64).collect();
        let mut panel = PanelWorkspace::new();
        panel.ensure_seed(&a, &b, m, n);
        let mut want_rows: Vec<Workspace> =
            (0..r).map(|_| Workspace::default()).collect();
        let mut want_status = vec![LpStatus::Unbounded; r];
        for i in 0..r {
            want_status[i] =
                panel.solve_row(&c[i * n..(i + 1) * n], &mut want_rows[i]);
        }
        for threads in 1..=r + 1 {
            let mut rows: Vec<Workspace> =
                (0..r).map(|_| Workspace::default()).collect();
            let mut statuses = vec![LpStatus::Infeasible; r];
            panel.solve_rows(&c, &mut rows, &mut statuses, threads);
            for i in 0..r {
                assert_bitwise(&format!("threads {} row {}", threads, i),
                               want_status[i], &want_rows[i], statuses[i],
                               &rows[i]);
            }
        }
    }
}
