//! Allocation-regression gate for the zero-copy native batch spine
//! (DESIGN.md §16): once a warmup pass has filled every arena, the
//! steady-state panel loop of EVERY registered task's native batch
//! backend must perform ZERO heap allocations.
//!
//! A counting `#[global_allocator]` wraps the system allocator, and the
//! whole suite lives in ONE `#[test]` function: libtest runs tests on
//! parallel threads, so a second test's allocations would pollute the
//! counter mid-window.  Backends run at `threads = 1`, where
//! `pool::parallel_try_jobs` executes the single chunk inline on the
//! calling thread — the zero-alloc contract this test pins covers the
//! whole dispatch path, not just the kernels.
//!
//! Everything a steady-state iteration consumes (keys, index draws,
//! panels, objective rows) is prebuilt OUTSIDE the measured window,
//! mirroring the drivers, which allocate their step buffers once per
//! run (`opt::panel::run_panel_ctl`, `opt::sqn::SqnHook`).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering};

use simopt::backend::native::{
    NativeCvarBatch, NativeLrBatch, NativeMvBatch, NativeNvBatch,
};
use simopt::backend::plane::tile_rows;
use simopt::backend::{
    HessianMode, LrBatchBackend, MvBatchBackend, NvBatchBackend,
};
use simopt::lp::PanelWorkspace;
use simopt::rng::{Philox, StreamTree};
use simopt::sim::{AssetUniverse, ClassifyData, NewsvendorInstance};
use simopt::tasks::cvar;
use simopt::tasks::newsvendor::NvLmo;
use simopt::tasks::BatchCorrectionMemory;

/// Counts every allocation request (alloc / alloc_zeroed / realloc);
/// frees are not counted — a steady-state loop that neither allocates
/// nor frees trivially satisfies both directions.
struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize)
        -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> usize {
    ALLOCS.load(Ordering::SeqCst)
}

/// Run `f` and assert it performed zero heap allocations.  The closure
/// must only touch borrowed, pre-sized buffers — exactly the property
/// under test.
fn assert_no_allocs<F: FnMut()>(label: &str, mut f: F) {
    let before = allocs();
    f();
    let delta = allocs() - before;
    assert_eq!(delta, 0,
               "{}: steady-state loop performed {} heap allocation(s); \
                the native batch hot path must be allocation-free after \
                warmup (DESIGN.md §16)",
               label, delta);
}

#[test]
fn steady_state_batch_loops_do_not_allocate() {
    // Sanity: the counting allocator is actually wired in.
    let before = allocs();
    let probe = vec![0u8; 256];
    drop(probe);
    assert!(allocs() > before, "counting allocator not installed");

    let (r, d, n_samples, m_inner) = (3usize, 8usize, 16usize, 3usize);
    let tree = StreamTree::new(0xA110C);
    let trees: Vec<StreamTree> =
        (0..r).map(|i| tree.subtree(&[1000 + i as u64])).collect();
    // epoch 0..2 warm the arenas, 2..5 are the measured window
    let (warmup, measured) = (2usize, 3usize);
    let keys: Vec<Vec<[u32; 2]>> = (0..warmup + measured)
        .map(|k| trees.iter().map(|t| t.jax_key(&[k as u64])).collect())
        .collect();

    // ---- Task 1: mean-variance epoch panels ------------------------------
    let u = AssetUniverse::generate(&tree, d);
    let mut batch = NativeMvBatch::new(&u, n_samples, m_inner, r, 1);
    let w0 = vec![1.0f32 / d as f32; d];
    let mut panel = tile_rows(&w0, r);
    let mut objs = vec![0.0f64; r];
    for k in 0..warmup {
        batch.epoch_batch(&mut panel, k, &keys[k], &mut objs).unwrap();
    }
    assert_no_allocs("mv epoch_batch", || {
        for k in warmup..warmup + measured {
            batch.epoch_batch(&mut panel, k, &keys[k], &mut objs).unwrap();
        }
    });

    // ---- Task 4: mean-CVaR epoch panels (joint [w, t] rows) --------------
    let mut batch = NativeCvarBatch::new(&u, n_samples, m_inner, r, 1);
    let mut panel = tile_rows(&cvar::start_iterate(d), r);
    for k in 0..warmup {
        batch.epoch_batch(&mut panel, k, &keys[k], &mut objs).unwrap();
    }
    assert_no_allocs("cvar epoch_batch", || {
        for k in warmup..warmup + measured {
            batch.epoch_batch(&mut panel, k, &keys[k], &mut objs).unwrap();
        }
    });

    // ---- Task 2: newsvendor gradient panels ------------------------------
    // distinct keys per step force `ensure_panel` to regenerate the MC
    // panel — in place, into the buffer sized at construction
    let inst = NewsvendorInstance::generate(&tree, d, 2, 0.6);
    let nd = inst.dim();
    let mut batch = NativeNvBatch::new(&inst, n_samples, r, 1);
    let x_panel = tile_rows(&inst.feasible_start(), r);
    let mut g = vec![0.0f32; r * nd];
    for k in 0..warmup {
        batch.grad_obj_batch(&x_panel, &keys[k], &mut g, &mut objs)
            .unwrap();
    }
    assert_no_allocs("nv grad_obj_batch", || {
        for k in warmup..warmup + measured {
            batch.grad_obj_batch(&x_panel, &keys[k], &mut g, &mut objs)
                .unwrap();
        }
    });

    // ---- Task 2: panel LMO (DESIGN.md §17) -------------------------------
    // At threads = 1 the row fan-out is the inline single-chunk path, so
    // after warmup the whole panel solve — shared-seed reuse check, column
    // generation, restricted simplex — must run allocation-free even as
    // the gradient panel changes every step.  The first warmup pass uses
    // an all-negative gradient so every CG arena (candidate pool, active
    // set, restricted tableau) reaches its maximum shape (k = d columns)
    // before the window; later steps only shrink.
    let mut lmos: Vec<NvLmo> = (0..r).map(|_| NvLmo::new(&inst)).collect();
    let mut lmo_seed = PanelWorkspace::new();
    let mut verts = vec![0.0f32; r * nd];
    let mut rng = Philox::new(0x1A0);
    let g_steps: Vec<Vec<f32>> = (0..warmup + measured)
        .map(|k| {
            if k == 0 {
                vec![-1.0f32; r * nd]
            } else {
                (0..r * nd).map(|_| rng.uniform_f32(-3.0, 2.0)).collect()
            }
        })
        .collect();
    for g in g_steps.iter().take(warmup) {
        NvLmo::solve_panel_into(&mut lmos, &mut lmo_seed, g, &mut verts, 1)
            .unwrap();
    }
    assert_no_allocs("nv panel lmo", || {
        for g in g_steps.iter().skip(warmup) {
            NvLmo::solve_panel_into(&mut lmos, &mut lmo_seed, g, &mut verts,
                                    1)
                .unwrap();
        }
    });

    // ---- Task 3: SQN gradient / HVP / push / direction cycles ------------
    // the full per-iteration cycle of the batched SQN driver, in both
    // Hessian modes: minibatch gradient, sub-sampled HVP, correction-pair
    // push (ring-evicting — the memory is filled to capacity during
    // warmup so `count` never grows inside the window), Algorithm-4
    // direction (explicit-H rebuilt IN PLACE every cycle, because
    // `hvp_batch` bumps the memory generation)
    let data = ClassifyData::generate(&tree, d);
    let n = data.n_features;
    let (bsz, hbsz, capacity) = (16usize, 8usize, 3usize);
    let cycles = warmup + measured;
    let idx: Vec<Vec<Vec<usize>>> = (0..cycles)
        .map(|c| {
            trees
                .iter()
                .map(|t| {
                    let mut rng = t.stream(&[2, c as u64]);
                    rng.sample_indices(data.n_samples, bsz)
                })
                .collect()
        })
        .collect();
    let hidx: Vec<Vec<Vec<usize>>> = (0..cycles)
        .map(|c| {
            trees
                .iter()
                .map(|t| {
                    let mut rng = t.stream(&[3, c as u64]);
                    rng.sample_indices(data.n_samples, hbsz)
                })
                .collect()
        })
        .collect();

    for mode in [HessianMode::Explicit, HessianMode::TwoLoop] {
        let label = match mode {
            HessianMode::Explicit => "lr cycle (explicit H)",
            HessianMode::TwoLoop => "lr cycle (two-loop)",
        };
        let mut batch = NativeLrBatch::new(&data, r, 1, mode);
        let mut mem = BatchCorrectionMemory::new(r, capacity, n);
        // saturate the ring during warmup: curvature > 0 by construction,
        // so every push is accepted and `count` reaches `capacity`
        for t in 0..capacity + 1 {
            for row in 0..r {
                let s: Vec<f32> =
                    (0..n).map(|j| 0.1 + ((t + row + j) % 5) as f32).collect();
                let y: Vec<f32> = s.iter().map(|&v| 1.5 * v + 0.01).collect();
                assert!(mem.push_row(row, &s, &y), "warmup pair rejected");
            }
        }
        let w_panel = vec![0.05f32; r * n];
        let mut g = vec![0.0f32; r * n];
        let mut losses = vec![0.0f64; r];
        let s_panel = vec![0.02f32; r * n];
        let mut y_panel = vec![0.0f32; r * n];
        let mut dirs = vec![0.0f32; r * n];
        let cycle = |c: usize,
                         batch: &mut NativeLrBatch,
                         mem: &mut BatchCorrectionMemory,
                         g: &mut [f32],
                         losses: &mut [f64],
                         y_panel: &mut [f32],
                         dirs: &mut [f32]| {
            batch.grad_batch(&w_panel, &data, &idx[c], g, losses).unwrap();
            batch
                .hvp_batch(&w_panel, &s_panel, &data, &hidx[c], y_panel)
                .unwrap();
            for row in 0..r {
                let _ = mem.push_row(row, &s_panel[row * n..(row + 1) * n],
                                     &y_panel[row * n..(row + 1) * n]);
            }
            batch.direction_batch(mem.view(), g, dirs).unwrap();
        };
        for c in 0..warmup {
            cycle(c, &mut batch, &mut mem, &mut g, &mut losses,
                  &mut y_panel, &mut dirs);
        }
        assert_no_allocs(label, || {
            for c in warmup..cycles {
                cycle(c, &mut batch, &mut mem, &mut g, &mut losses,
                      &mut y_panel, &mut dirs);
            }
        });
    }
}
