//! Profiler invariance suite (DESIGN.md §15).
//!
//! The always-on per-phase profiler must observe, never perturb.  For
//! EVERY registered task, on the sequential plan, the single-panel
//! batched engine, and an uneven sharded plan:
//!
//! * the numeric trace of a profiled run is bitwise-identical across
//!   plans and across re-runs — the probe reads sit outside the timed
//!   regions, so there is no seed-behavior drift to hide;
//! * the profile is populated (the profiler is always on, not opt-in);
//! * the per-phase totals are internally consistent with the measured
//!   wall-clock: never more than the run's total attribution budget,
//!   and — when the workload is large enough to measure — within a
//!   coarse tolerance of the stepped wall, which catches both
//!   double-booking (a phase counted by the driver AND the backend) and
//!   a silently dead probe.

use simopt::config::ExecMode;
use simopt::coordinator::{Coordinator, RunResult};
use simopt::tasks::registry;

fn coord() -> Coordinator {
    Coordinator::new("artifacts", "/tmp/simopt-profile-invariance").unwrap()
}

fn plans() -> [ExecMode; 3] {
    [ExecMode::Sequential, ExecMode::Batched { shards: 1 },
     ExecMode::Batched { shards: 2 }]
}

/// Σ over replications of the stepped wall — the portion of the run the
/// per-phase attribution is expected to cover.
fn stepped_wall(r: &RunResult) -> f64 {
    r.reps.iter().map(|rep| rep.step_s.iter().sum::<f64>()).sum()
}

#[test]
fn profiled_runs_are_bitwise_identical_across_plans_and_reruns() {
    let mut c = coord();
    for task in registry::all() {
        let mut baseline: Option<RunResult> = None;
        for exec in plans() {
            let mut spec = task.smoke_spec();
            spec.reps = 3; // shards = 2 is an uneven 2+1 split
            spec.exec = exec;
            let got = c.run(&spec).unwrap();
            let again = c.run(&spec).unwrap();
            // re-running the identical spec reproduces every objective
            // bit — the probes read clocks, not state
            for (a, b) in got.reps.iter().zip(&again.reps) {
                assert_eq!(a.objs, b.objs, "task {} exec {:?}: profiled \
                           re-run must be deterministic",
                           task.name(), exec);
                assert_eq!(a.obj_iters, b.obj_iters);
            }
            match &baseline {
                None => baseline = Some(got),
                Some(want) => {
                    // and every plan agrees with the sequential protocol
                    assert_eq!(want.reps.len(), got.reps.len());
                    for (a, b) in want.reps.iter().zip(&got.reps) {
                        assert_eq!(a.objs, b.objs, "task {} exec {:?}",
                                   task.name(), exec);
                        assert_eq!(a.obj_iters, b.obj_iters,
                                   "task {} exec {:?}", task.name(), exec);
                    }
                }
            }
        }
    }
}

#[test]
fn every_plan_populates_the_profile() {
    let mut c = coord();
    for task in registry::all() {
        for exec in plans() {
            let mut spec = task.smoke_spec();
            spec.reps = 3;
            spec.exec = exec;
            let got = c.run(&spec).unwrap();
            assert!(!got.profile.is_empty(),
                    "task {} exec {:?}: the always-on profiler left no \
                     per-phase seconds behind", task.name(), exec);
            assert!(got.profile.sum() > 0.0);
            // the wire payload carries it too
            let text = got.to_json().to_string_compact();
            assert!(text.contains("\"per_phase\":{"), "{}", text);
        }
    }
}

#[test]
fn per_phase_totals_are_consistent_with_wall_clock() {
    let mut c = coord();
    for task in registry::all() {
        for exec in plans() {
            let mut spec = task.smoke_spec();
            spec.reps = 3;
            spec.exec = exec;
            let got = c.run(&spec).unwrap();
            let attributed = got.profile.sum();
            let total: f64 = got.reps.iter().map(|r| r.total_s).sum();
            // attribution can never exceed the measured wall (timer
            // jitter allowance aside) — the double-booking guard
            assert!(attributed <= total * 1.10 + 0.005,
                    "task {} exec {:?}: attributed {:.6}s > wall {:.6}s",
                    task.name(), exec, attributed, total);
            // smoke workloads can be microseconds long, where the
            // tolerance would dwarf the signal; only gate the coverage
            // side when there is something to measure
            let stepped = stepped_wall(&got);
            if stepped > 0.02 {
                assert!((attributed - stepped).abs()
                            <= stepped * 0.25 + 0.005,
                        "task {} exec {:?}: attributed {:.6}s vs stepped \
                         wall {:.6}s", task.name(), exec, attributed,
                        stepped);
            }
        }
    }
}
