//! The served arm of the registry-conformance suite (DESIGN.md §14).
//!
//! For EVERY registered task, results obtained through an in-process
//! `simopt serve` instance over a temp socket must be bit-identical to
//! the direct `Coordinator::run` of the same spec — on the sequential
//! plan, the batched plan, and a sharded plan — and the service contracts
//! hold: a repeat submission answers from the content-addressed cache
//! with no re-execution, a full admission queue answers a typed `busy`
//! frame, and invalid specs answer typed `error` frames.  Registering a
//! new scenario must pass this suite with zero suite changes.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::thread::JoinHandle;

use simopt::config::ExecMode;
use simopt::coordinator::Coordinator;
use simopt::service::{Client, Response, Server, ServerConfig, ServerStats};
use simopt::tasks::registry;

fn temp_socket(tag: &str) -> PathBuf {
    static N: AtomicU64 = AtomicU64::new(0);
    std::env::temp_dir().join(format!(
        "simopt-{}-{}-{}.sock",
        tag,
        std::process::id(),
        N.fetch_add(1, Ordering::Relaxed)
    ))
}

fn results_dir() -> String {
    std::env::temp_dir()
        .join("simopt_service_conformance")
        .to_string_lossy()
        .into_owned()
}

/// Bind + run an in-process server; the socket exists when this returns.
fn spawn_server(tag: &str, workers: usize, queue: usize)
    -> (PathBuf, JoinHandle<ServerStats>) {
    let socket = temp_socket(tag);
    let server = Server::bind(ServerConfig {
        socket: socket.clone(),
        artifact_dir: "artifacts".into(),
        results_dir: results_dir(),
        workers,
        queue_capacity: queue,
        cache_capacity: 64,
    })
    .unwrap();
    let handle = std::thread::spawn(move || server.run().unwrap());
    (socket, handle)
}

fn shut_down(socket: &PathBuf, handle: JoinHandle<ServerStats>)
    -> ServerStats {
    Client::connect(socket).unwrap().shutdown().unwrap();
    handle.join().unwrap()
}

#[test]
fn served_results_are_bitwise_identical_to_direct_runs_for_every_task() {
    let (socket, handle) = spawn_server("conf", 1, 8);
    let mut direct = Coordinator::new("artifacts", &results_dir()).unwrap();
    for task in registry::all() {
        // seq, the single-panel batched engine, and an uneven sharded plan
        for exec in [ExecMode::Sequential, ExecMode::Batched { shards: 1 },
                     ExecMode::Batched { shards: 2 }] {
            let mut spec = task.smoke_spec();
            spec.reps = 3; // makes shards=2 an uneven 2+1 split
            spec.exec = exec;
            let want = direct.run(&spec).unwrap();
            let mut client = Client::connect(&socket).unwrap();
            match client.submit(&spec).unwrap() {
                Response::Completed { cache_hit, result, .. } => {
                    assert!(!cache_hit, "task {} exec {:?}: first \
                             submission cannot hit the cache",
                            task.name(), exec);
                    // the deterministic payloads are byte-identical…
                    assert_eq!(
                        result.canonical_json().to_string_pretty(),
                        want.canonical_json().to_string_pretty(),
                        "task {} exec {:?}", task.name(), exec
                    );
                    // …which includes bitwise-equal objective traces and
                    // the resolved plan
                    assert_eq!(result.shards, want.shards);
                    assert_eq!(result.batched, want.batched);
                    for (a, b) in want.reps.iter().zip(&result.reps) {
                        assert_eq!(a.objs, b.objs,
                                   "task {} exec {:?}", task.name(), exec);
                        assert_eq!(a.obj_iters, b.obj_iters);
                    }
                }
                other => panic!("task {} exec {:?}: expected a result, \
                                 got {:?}", task.name(), exec, other),
            }
        }
    }
    let stats = shut_down(&socket, handle);
    // 4 tasks × 3 exec plans, every one executed (no accidental hits)
    assert_eq!(stats.executed, (registry::all().count() * 3) as u64);
    assert_eq!(stats.cache_hits, 0);
}

#[test]
fn repeat_submission_answers_from_the_cache_without_reexecution() {
    let (socket, handle) = spawn_server("cache", 1, 4);
    for task in registry::all() {
        let spec = task.smoke_spec();
        let first = match Client::connect(&socket).unwrap()
            .submit(&spec).unwrap() {
            Response::Completed { cache_hit, result, .. } => {
                assert!(!cache_hit, "task {}", task.name());
                result
            }
            other => panic!("{:?}", other),
        };
        // identical spec → served from the cache, payload identical
        match Client::connect(&socket).unwrap().submit(&spec).unwrap() {
            Response::Completed { cache_hit, result, .. } => {
                assert!(cache_hit, "task {}: resubmission must hit",
                        task.name());
                assert_eq!(result.to_json().to_string_compact(),
                           first.to_json().to_string_compact(),
                           "task {}: cached payload must be the stored \
                            one, byte for byte", task.name());
            }
            other => panic!("{:?}", other),
        }
        // a spec differing only in its results directory is the same
        // computation — still a hit (delivery is not content)…
        let reloc_dir = std::path::PathBuf::from(results_dir())
            .join(format!("relocated-{}", task.name()));
        let _ = std::fs::remove_dir_all(&reloc_dir);
        let relocated =
            spec.clone().results_dir(&reloc_dir.to_string_lossy());
        match Client::connect(&socket).unwrap()
            .submit(&relocated).unwrap() {
            Response::Completed { cache_hit, result, .. } => {
                assert!(cache_hit, "task {}: results_dir must not change \
                         the cache key", task.name());
                // …and the cached payload never leaks anyone's delivery
                // directory (it embeds the canonical spec)
                assert_eq!(result.spec.results_dir, None);
            }
            other => panic!("{:?}", other),
        }
        // …but the requested delivery still happens, report bundle and
        // all, with zero re-execution (bundle named by label + spec hash
        // so sibling runs in one directory never overwrite each other)
        let bundle = reloc_dir.join(format!(
            "run_{}_{:016x}_summary.json", spec.label(), spec.spec_hash()));
        assert!(bundle.exists(), "task {}: cache-hit delivery missing \
                 {}", task.name(), bundle.display());
        // a different seed is different content — miss
        let reseeded = spec.clone().seed(spec.seed + 1);
        match Client::connect(&socket).unwrap()
            .submit(&reseeded).unwrap() {
            Response::Completed { cache_hit, .. } => {
                assert!(!cache_hit, "task {}", task.name());
            }
            other => panic!("{:?}", other),
        }
    }
    let stats = shut_down(&socket, handle);
    let tasks = registry::all().count() as u64;
    assert_eq!(stats.executed, 2 * tasks, "base + reseeded per task");
    assert_eq!(stats.cache_hits, 2 * tasks, "resubmit + relocated per task");
    assert_eq!(stats.cache_entries as u64, 2 * tasks);
}

#[test]
fn full_queue_answers_typed_busy_instead_of_hanging() {
    // capacity 0 admits nothing: the deterministic backpressure arm
    let (socket, handle) = spawn_server("busy", 1, 0);
    let spec = registry::all().next().unwrap().smoke_spec();
    match Client::connect(&socket).unwrap().submit(&spec).unwrap() {
        Response::Busy { capacity } => assert_eq!(capacity, 0),
        other => panic!("expected busy, got {:?}", other),
    }
    // backpressure is per-submission, not a wedged server: status still
    // answers, and shutdown still drains cleanly
    let st = Client::connect(&socket).unwrap().status().unwrap();
    assert_eq!(st.queue_depth, 0);
    assert_eq!(st.capacity, 0);
    assert_eq!(st.executed, 0);
    let stats = shut_down(&socket, handle);
    assert_eq!(stats.executed, 0);
}

#[test]
fn invalid_and_malformed_submissions_answer_typed_errors() {
    let (socket, handle) = spawn_server("err", 1, 4);
    // semantically invalid: reps == 0 fails spec validation server-side
    let mut spec = registry::all().next().unwrap().smoke_spec();
    spec.reps = 0;
    match Client::connect(&socket).unwrap().submit(&spec).unwrap() {
        Response::Error { message } => {
            assert!(message.contains("reps"), "{}", message)
        }
        other => panic!("expected an error frame, got {:?}", other),
    }
    // shards > reps dies at validation too, as a frame, not a hang
    let mut spec = registry::all().next().unwrap().smoke_spec();
    spec.exec = ExecMode::Batched { shards: 9 };
    match Client::connect(&socket).unwrap().submit(&spec).unwrap() {
        Response::Error { message } => {
            assert!(message.contains("shards"), "{}", message)
        }
        other => panic!("{:?}", other),
    }
    let stats = shut_down(&socket, handle);
    assert_eq!(stats.executed, 0, "invalid specs never execute");
    handle_is_gone(&socket);
}

/// After shutdown the socket file is gone and connects fail.
fn handle_is_gone(socket: &PathBuf) {
    assert!(!socket.exists(), "socket file must be removed on shutdown");
    assert!(Client::connect(socket).is_err());
}

#[test]
fn status_counters_track_the_conversation() {
    let (socket, handle) = spawn_server("status", 1, 4);
    let st = Client::connect(&socket).unwrap().status().unwrap();
    assert_eq!((st.executed, st.cache_hits, st.cache_entries), (0, 0, 0));
    assert_eq!(st.workers, 1);
    assert_eq!(st.capacity, 4);
    let spec = registry::all().next().unwrap().smoke_spec();
    for _ in 0..2 {
        match Client::connect(&socket).unwrap().submit(&spec).unwrap() {
            Response::Completed { .. } => {}
            other => panic!("{:?}", other),
        }
    }
    let st = Client::connect(&socket).unwrap().status().unwrap();
    assert_eq!(st.executed, 1, "one execution, one cache hit");
    assert_eq!(st.cache_hits, 1);
    assert_eq!(st.cache_entries, 1);
    let stats = shut_down(&socket, handle);
    assert_eq!(stats.executed, 1);
    assert_eq!(stats.cache_hits, 1);
}
