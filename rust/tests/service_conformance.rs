//! The served arm of the registry-conformance suite (DESIGN.md §14).
//!
//! For EVERY registered task, results obtained through an in-process
//! `simopt serve` instance over a temp socket must be bit-identical to
//! the direct `Coordinator::run` of the same spec — on the sequential
//! plan, the batched plan, and a sharded plan — and the service contracts
//! hold: a repeat submission answers from the content-addressed cache
//! with no re-execution, a full admission queue answers a typed `busy`
//! frame, and invalid specs answer typed `error` frames.  Registering a
//! new scenario must pass this suite with zero suite changes.

use std::io::{BufReader, Write};
use std::os::unix::net::UnixStream;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::thread::JoinHandle;

use simopt::config::{BudgetPolicy, ExecMode};
use simopt::coordinator::{Coordinator, ExperimentSpec};
use simopt::service::protocol::{read_frame, write_frame};
use simopt::service::{Client, Response, Server, ServerConfig, ServerStats,
                      PROTOCOL_VERSION};
use simopt::tasks::registry;
use simopt::util::json::{num, obj, s, Value};

fn temp_socket(tag: &str) -> PathBuf {
    static N: AtomicU64 = AtomicU64::new(0);
    std::env::temp_dir().join(format!(
        "simopt-{}-{}-{}.sock",
        tag,
        std::process::id(),
        N.fetch_add(1, Ordering::Relaxed)
    ))
}

fn results_dir() -> String {
    std::env::temp_dir()
        .join("simopt_service_conformance")
        .to_string_lossy()
        .into_owned()
}

/// Bind + run an in-process server; the socket exists when this returns.
fn spawn_server(tag: &str, workers: usize, queue: usize)
    -> (PathBuf, JoinHandle<ServerStats>) {
    let socket = temp_socket(tag);
    let server = Server::bind(ServerConfig {
        socket: socket.clone(),
        artifact_dir: "artifacts".into(),
        results_dir: results_dir(),
        workers,
        queue_capacity: queue,
        cache_capacity: 64,
        trace_out: None,
    })
    .unwrap();
    let handle = std::thread::spawn(move || server.run().unwrap());
    (socket, handle)
}

fn shut_down(socket: &PathBuf, handle: JoinHandle<ServerStats>)
    -> ServerStats {
    Client::connect(socket).unwrap().shutdown().unwrap();
    handle.join().unwrap()
}

/// One non-streaming submission over the Session API — the suite's only
/// submit path; the deprecated `Client::submit`/`submit_with` wrappers are
/// exercised solely by `deprecated_submit_wrappers_still_speak_the_\
/// session_grammar`.
fn submit(socket: &PathBuf, spec: &ExperimentSpec) -> Response {
    Client::connect(socket)
        .unwrap()
        .session(spec, false)
        .unwrap()
        .finish()
        .unwrap()
}

#[test]
fn served_results_are_bitwise_identical_to_direct_runs_for_every_task() {
    let (socket, handle) = spawn_server("conf", 1, 8);
    let mut direct = Coordinator::new("artifacts", &results_dir()).unwrap();
    for task in registry::all() {
        // seq, the single-panel batched engine, and an uneven sharded plan
        for exec in [ExecMode::Sequential, ExecMode::Batched { shards: 1 },
                     ExecMode::Batched { shards: 2 }] {
            let mut spec = task.smoke_spec();
            spec.reps = 3; // makes shards=2 an uneven 2+1 split
            spec.exec = exec;
            let want = direct.run(&spec).unwrap();
            match submit(&socket, &spec) {
                Response::Completed { cache_hit, result, .. } => {
                    assert!(!cache_hit, "task {} exec {:?}: first \
                             submission cannot hit the cache",
                            task.name(), exec);
                    // the deterministic payloads are byte-identical…
                    assert_eq!(
                        result.canonical_json().to_string_pretty(),
                        want.canonical_json().to_string_pretty(),
                        "task {} exec {:?}", task.name(), exec
                    );
                    // …which includes bitwise-equal objective traces and
                    // the resolved plan
                    assert_eq!(result.shards, want.shards);
                    assert_eq!(result.batched, want.batched);
                    for (a, b) in want.reps.iter().zip(&result.reps) {
                        assert_eq!(a.objs, b.objs,
                                   "task {} exec {:?}", task.name(), exec);
                        assert_eq!(a.obj_iters, b.obj_iters);
                    }
                }
                other => panic!("task {} exec {:?}: expected a result, \
                                 got {:?}", task.name(), exec, other),
            }
        }
    }
    let stats = shut_down(&socket, handle);
    // 4 tasks × 3 exec plans, every one executed (no accidental hits)
    assert_eq!(stats.executed, (registry::all().count() * 3) as u64);
    assert_eq!(stats.cache_hits, 0);
}

#[test]
fn repeat_submission_answers_from_the_cache_without_reexecution() {
    let (socket, handle) = spawn_server("cache", 1, 4);
    for task in registry::all() {
        let spec = task.smoke_spec();
        let first = match submit(&socket, &spec) {
            Response::Completed { cache_hit, result, .. } => {
                assert!(!cache_hit, "task {}", task.name());
                result
            }
            other => panic!("{:?}", other),
        };
        // identical spec → served from the cache, payload identical
        match submit(&socket, &spec) {
            Response::Completed { cache_hit, result, .. } => {
                assert!(cache_hit, "task {}: resubmission must hit",
                        task.name());
                assert_eq!(result.to_json().to_string_compact(),
                           first.to_json().to_string_compact(),
                           "task {}: cached payload must be the stored \
                            one, byte for byte", task.name());
            }
            other => panic!("{:?}", other),
        }
        // a spec differing only in its results directory is the same
        // computation — still a hit (delivery is not content)…
        let reloc_dir = std::path::PathBuf::from(results_dir())
            .join(format!("relocated-{}", task.name()));
        let _ = std::fs::remove_dir_all(&reloc_dir);
        let relocated =
            spec.clone().results_dir(&reloc_dir.to_string_lossy());
        match submit(&socket, &relocated) {
            Response::Completed { cache_hit, result, .. } => {
                assert!(cache_hit, "task {}: results_dir must not change \
                         the cache key", task.name());
                // …and the cached payload never leaks anyone's delivery
                // directory (it embeds the canonical spec)
                assert_eq!(result.spec.results_dir, None);
            }
            other => panic!("{:?}", other),
        }
        // …but the requested delivery still happens, report bundle and
        // all, with zero re-execution (bundle named by label + spec hash
        // so sibling runs in one directory never overwrite each other)
        let bundle = reloc_dir.join(format!(
            "run_{}_{:016x}_summary.json", spec.label(), spec.spec_hash()));
        assert!(bundle.exists(), "task {}: cache-hit delivery missing \
                 {}", task.name(), bundle.display());
        // a different seed is different content — miss
        let reseeded = spec.clone().seed(spec.seed + 1);
        match submit(&socket, &reseeded) {
            Response::Completed { cache_hit, .. } => {
                assert!(!cache_hit, "task {}", task.name());
            }
            other => panic!("{:?}", other),
        }
    }
    let stats = shut_down(&socket, handle);
    let tasks = registry::all().count() as u64;
    assert_eq!(stats.executed, 2 * tasks, "base + reseeded per task");
    assert_eq!(stats.cache_hits, 2 * tasks, "resubmit + relocated per task");
    assert_eq!(stats.cache_entries as u64, 2 * tasks);
}

#[test]
fn full_queue_answers_typed_busy_instead_of_hanging() {
    // capacity 0 admits nothing: the deterministic backpressure arm
    let (socket, handle) = spawn_server("busy", 1, 0);
    let spec = registry::all().next().unwrap().smoke_spec();
    match submit(&socket, &spec) {
        Response::Busy { capacity } => assert_eq!(capacity, 0),
        other => panic!("expected busy, got {:?}", other),
    }
    // backpressure is per-submission, not a wedged server: status still
    // answers, and shutdown still drains cleanly
    let st = Client::connect(&socket).unwrap().status().unwrap();
    assert_eq!(st.queue_depth, 0);
    assert_eq!(st.capacity, 0);
    assert_eq!(st.executed, 0);
    let stats = shut_down(&socket, handle);
    assert_eq!(stats.executed, 0);
}

#[test]
fn invalid_and_malformed_submissions_answer_typed_errors() {
    let (socket, handle) = spawn_server("err", 1, 4);
    // semantically invalid: reps == 0 fails spec validation server-side
    let mut spec = registry::all().next().unwrap().smoke_spec();
    spec.reps = 0;
    match submit(&socket, &spec) {
        Response::Error { message } => {
            assert!(message.contains("reps"), "{}", message)
        }
        other => panic!("expected an error frame, got {:?}", other),
    }
    // shards > reps dies at validation too, as a frame, not a hang
    let mut spec = registry::all().next().unwrap().smoke_spec();
    spec.exec = ExecMode::Batched { shards: 9 };
    match submit(&socket, &spec) {
        Response::Error { message } => {
            assert!(message.contains("shards"), "{}", message)
        }
        other => panic!("{:?}", other),
    }
    let stats = shut_down(&socket, handle);
    assert_eq!(stats.executed, 0, "invalid specs never execute");
    handle_is_gone(&socket);
}

/// After shutdown the socket file is gone and connects fail.
fn handle_is_gone(socket: &PathBuf) {
    assert!(!socket.exists(), "socket file must be removed on shutdown");
    assert!(Client::connect(socket).is_err());
}

#[test]
fn status_counters_track_the_conversation() {
    let (socket, handle) = spawn_server("status", 1, 4);
    let st = Client::connect(&socket).unwrap().status().unwrap();
    assert_eq!((st.executed, st.cache_hits, st.cache_entries), (0, 0, 0));
    assert_eq!(st.workers, 1);
    assert_eq!(st.capacity, 4);
    let spec = registry::all().next().unwrap().smoke_spec();
    for _ in 0..2 {
        match submit(&socket, &spec) {
            Response::Completed { .. } => {}
            other => panic!("{:?}", other),
        }
    }
    let st = Client::connect(&socket).unwrap().status().unwrap();
    assert_eq!(st.executed, 1, "one execution, one cache hit");
    assert_eq!(st.cache_hits, 1);
    assert_eq!(st.cache_entries, 1);
    // the structured stats object (protocol v2): per-worker counters and
    // aggregate per-phase seconds from the always-on profiler
    assert_eq!(st.per_worker.len(), 1);
    assert_eq!(st.per_worker[0].executed, 1);
    assert_eq!(st.per_worker[0].cache_hits, 0,
               "the repeat answered from the handler fast path, which \
                counts only in the global cache totals");
    assert!(!st.per_phase.is_empty(),
            "an executed run must leave per-phase seconds behind");
    let stats = shut_down(&socket, handle);
    assert_eq!(stats.executed, 1);
    assert_eq!(stats.cache_hits, 1);
}

#[test]
fn deprecated_submit_wrappers_still_speak_the_session_grammar() {
    // `Client::submit` / `submit_with` are doc-deprecated conveniences
    // kept for external callers; this is their single remaining exercise
    // — every other submission in the suite rides the Session API.
    let (socket, handle) = spawn_server("compat", 1, 4);
    let spec = registry::all().next().unwrap().smoke_spec();
    let mut queued = 0usize;
    match Client::connect(&socket).unwrap()
        .submit_with(&spec, |_, _| queued += 1).unwrap() {
        Response::Completed { cache_hit, .. } => assert!(!cache_hit),
        other => panic!("{:?}", other),
    }
    assert_eq!(queued, 1, "the wrapper must surface the queued ack");
    match Client::connect(&socket).unwrap().submit(&spec).unwrap() {
        Response::Completed { cache_hit, result, .. } => {
            assert!(cache_hit, "wrappers share the session cache path");
            assert_eq!(result.spec.task, spec.task);
        }
        other => panic!("{:?}", other),
    }
    let stats = shut_down(&socket, handle);
    assert_eq!(stats.executed, 1);
    assert_eq!(stats.cache_hits, 1);
}

#[test]
fn streaming_submissions_keep_the_terminal_payload_bitwise_identical() {
    // With `stream` on and no budget policy, the only difference from a
    // plain submit is the interim `progress` frames: the terminal payload
    // must stay byte-identical to a direct run — for EVERY registered
    // task, on the sequential, batched, and sharded plans.
    let (socket, handle) = spawn_server("stream", 1, 8);
    let mut direct = Coordinator::new("artifacts", &results_dir()).unwrap();
    let mut plans = 0u64;
    for task in registry::all() {
        for exec in [ExecMode::Sequential, ExecMode::Batched { shards: 1 },
                     ExecMode::Batched { shards: 2 }] {
            let mut spec = task.smoke_spec();
            spec.reps = 3;
            spec.exec = exec;
            let want = direct.run(&spec).unwrap();
            let mut client = Client::connect(&socket).unwrap();
            let session = client.session(&spec, true).unwrap();
            let mut progress = 0usize;
            let resp = session
                .finish_with(|p| {
                    assert!(p.epoch >= 1 && p.epoch <= p.epochs,
                            "task {} exec {:?}", task.name(), exec);
                    assert_eq!(p.reps.len(), p.objs.len());
                    assert!(p.live >= 1 && p.live <= p.reps.len());
                    progress += 1;
                })
                .unwrap();
            match resp {
                Response::Completed { cache_hit, result, .. } => {
                    assert!(!cache_hit,
                            "task {} exec {:?}", task.name(), exec);
                    assert!(progress >= 1,
                            "task {} exec {:?}: a streaming submit must \
                             see progress frames", task.name(), exec);
                    assert_eq!(
                        result.canonical_json().to_string_pretty(),
                        want.canonical_json().to_string_pretty(),
                        "task {} exec {:?}: streaming must not perturb \
                         the payload", task.name(), exec
                    );
                    assert!(result.frozen.is_empty(),
                            "no budget policy, no freezes");
                    assert_eq!(result.early_stop, None);
                }
                other => panic!("task {} exec {:?}: {:?}",
                                task.name(), exec, other),
            }
            plans += 1;
        }
    }
    let stats = shut_down(&socket, handle);
    assert_eq!(stats.executed, plans);
    assert_eq!(stats.cache_hits, 0);
}

#[test]
fn budget_submissions_stream_shrinking_live_sets_and_record_freezes() {
    let (socket, handle) = spawn_server("budget", 1, 4);
    let mut spec = registry::all().next().unwrap().smoke_spec();
    spec.reps = 3;
    spec.exec = ExecMode::Batched { shards: 1 };
    // gap 0 freezes every strictly-dominated row at the first checkpoint;
    // tol 0 keeps early stop out of the picture
    spec.budget = Some(BudgetPolicy { check_every: 1, gap: 0.0, tol: 0.0 });
    let mut client = Client::connect(&socket).unwrap();
    let session = client.session(&spec, true).unwrap();
    let mut last_live = usize::MAX;
    let resp = session
        .finish_with(|p| {
            assert!(p.live <= p.reps.len());
            last_live = p.live;
        })
        .unwrap();
    match resp {
        Response::Completed { cache_hit, result, .. } => {
            assert!(!cache_hit);
            assert!(!result.frozen.is_empty(),
                    "gap 0 must freeze the dominated rows");
            assert!(result.frozen.len() < spec.reps,
                    "the incumbent can never freeze");
            assert!(last_live < spec.reps,
                    "late progress frames must see the shrunk live set");
            // the freeze decisions ride on the wire payload (what the CI
            // smoke greps out of `--out`)
            let payload = result.to_json().to_string_compact();
            assert!(payload.contains("\"frozen\""), "{}", payload);
        }
        other => panic!("{:?}", other),
    }
    let stats = shut_down(&socket, handle);
    assert_eq!(stats.executed, 1);
    assert_eq!(stats.cache_hits, 0);
}

#[test]
fn raw_v1_conversations_are_served_verbatim_by_the_v2_server() {
    let (socket, handle) = spawn_server("v1", 1, 4);
    let spec = registry::all().next().unwrap().smoke_spec();
    let stream = UnixStream::connect(&socket).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    // a v1 submit — even one carrying the v2-only `stream` key — answers
    // in the v1 grammar: queued ack, then the terminal result, with the
    // whole conversation stamped v1 and no progress frames in between
    let frame = obj(vec![
        ("v", num(1.0)),
        ("type", s("submit")),
        ("stream", Value::Bool(true)),
        ("spec", spec.to_json()),
    ]);
    write_frame(&mut writer, &frame).unwrap();
    let ack = read_frame(&mut reader).unwrap().unwrap();
    assert_eq!(ack.get("v").and_then(Value::as_uint), Some(1));
    assert_eq!(ack.get("type").and_then(Value::as_str), Some("queued"));
    let term = read_frame(&mut reader).unwrap().unwrap();
    assert_eq!(term.get("v").and_then(Value::as_uint), Some(1));
    assert_eq!(term.get("type").and_then(Value::as_str), Some("result"),
               "a v1 conversation must never see progress frames");
    // the embedded payload speaks the v1 grammar too: a deployed v1
    // client's RunResult::from_json is strict about the flat top-level
    // batched/shards keys and has never heard of "plan"
    let payload = term.get("result").expect("result frame has a payload");
    assert!(payload.get("plan").is_none(),
            "'plan' is v2 grammar; a v1 payload must stay flat");
    assert!(matches!(payload.get("batched"), Some(Value::Bool(_))),
            "v1 payload must carry the flat 'batched' key");
    assert!(payload.get("shards").and_then(Value::as_uint).is_some(),
            "v1 payload must carry the flat 'shards' key");
    assert!(payload.get("spec").is_some()
                && payload.get("records").and_then(Value::as_arr).is_some(),
            "v1 payload must carry 'spec' and 'records'");
    // and it decodes through the shared codec's legacy branch
    simopt::coordinator::RunResult::from_json(payload).unwrap();
    assert_eq!(read_frame(&mut reader).unwrap(), None,
               "one request per connection");
    let stats = shut_down(&socket, handle);
    assert_eq!(stats.executed, 1);
}

#[test]
fn out_of_range_versions_answer_the_typed_ceiling() {
    let (socket, handle) = spawn_server("vmax", 1, 4);
    let stream = UnixStream::connect(&socket).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    write_frame(&mut writer,
                &obj(vec![("v", num(9.0)), ("type", s("status"))]))
        .unwrap();
    let ans = read_frame(&mut reader).unwrap().unwrap();
    assert_eq!(ans.get("type").and_then(Value::as_str),
               Some("unsupported_version"));
    assert_eq!(ans.get("max").and_then(Value::as_uint),
               Some(PROTOCOL_VERSION),
               "the refusal must name the server's ceiling");
    assert_eq!(ans.get("v").and_then(Value::as_uint),
               Some(PROTOCOL_VERSION));
    assert_eq!(read_frame(&mut reader).unwrap(), None);
    let stats = shut_down(&socket, handle);
    assert_eq!(stats.executed, 0);
}

#[test]
fn truncated_frames_and_unknown_keys_do_not_wedge_the_server() {
    let (socket, handle) = spawn_server("robust", 1, 4);
    // a client dying mid-frame gets a typed error, not a hang
    let stream = UnixStream::connect(&socket).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    writer.write_all(br#"{"v":2,"type":"sub"#).unwrap();
    writer.shutdown(std::net::Shutdown::Write).unwrap();
    let ans = read_frame(&mut reader).unwrap().unwrap();
    assert_eq!(ans.get("type").and_then(Value::as_str), Some("error"));
    // unknown top-level keys are foreign grammar, ignored — not a parse
    // error (what lets v1 servers skip a v2 `stream` key)
    let stream = UnixStream::connect(&socket).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let mut writer = stream;
    write_frame(&mut writer, &obj(vec![
        ("v", num(2.0)),
        ("type", s("status")),
        ("x-extension", s("ignored")),
    ]))
    .unwrap();
    let ans = read_frame(&mut reader).unwrap().unwrap();
    assert_eq!(ans.get("type").and_then(Value::as_str), Some("status"));
    // and the server is still fully operational afterwards
    let spec = registry::all().next().unwrap().smoke_spec();
    match submit(&socket, &spec) {
        Response::Completed { .. } => {}
        other => panic!("{:?}", other),
    }
    let stats = shut_down(&socket, handle);
    assert_eq!(stats.executed, 1);
}

#[test]
fn interleaved_streaming_sessions_never_cross_talk() {
    let (socket, handle) = spawn_server("interleave", 2, 8);
    let mut direct = Coordinator::new("artifacts", &results_dir()).unwrap();
    let mut specs = Vec::new();
    for task in registry::all().take(2) {
        let mut spec = task.smoke_spec();
        spec.reps = 3;
        spec.exec = ExecMode::Batched { shards: 1 };
        specs.push(spec);
    }
    let wants: Vec<String> = specs
        .iter()
        .map(|s| direct.run(s).unwrap().canonical_json().to_string_pretty())
        .collect();
    // two concurrent streaming conversations on two workers: every frame
    // a session sees must carry its own id, and each terminal payload
    // must be the session's own run
    let threads: Vec<_> = specs
        .into_iter()
        .map(|spec| {
            let socket = socket.clone();
            std::thread::spawn(move || -> (usize, String) {
                let mut client = Client::connect(&socket).unwrap();
                let mut session = client.session(&spec, true).unwrap();
                let mut sid = None;
                let mut progress = 0usize;
                loop {
                    match session.next_event().unwrap() {
                        Some(Response::Queued { id, .. }) => sid = Some(id),
                        Some(Response::Progress(p)) => {
                            assert_eq!(Some(p.id), sid,
                                       "progress frame leaked across \
                                        sessions");
                            progress += 1;
                        }
                        Some(Response::Completed { id, result, .. }) => {
                            assert_eq!(Some(id), sid);
                            return (progress,
                                    result.canonical_json()
                                        .to_string_pretty());
                        }
                        Some(other) => panic!("{:?}", other),
                        None => panic!("session ended without a terminal \
                                        frame"),
                    }
                }
            })
        })
        .collect();
    for (t, want) in threads.into_iter().zip(&wants) {
        let (progress, got) = t.join().unwrap();
        assert!(progress >= 1);
        assert_eq!(&got, want, "each session must stream its own run");
    }
    let stats = shut_down(&socket, handle);
    assert_eq!(stats.executed, 2);
    assert_eq!(stats.cache_hits, 0);
}
