//! Property-based invariants over the coordinator's substrates, via the
//! in-tree mini harness (`simopt::util::prop`): LP optimality/feasibility,
//! FW iterate feasibility, LMO agreement between the analytic rule and the
//! LP solver, RNG stream hygiene, JSON round-trips, and stats identities.

use simopt::lp::{self, LpProblem, LpResult};
use simopt::rng::{Philox, StreamTree};
use simopt::tasks::mean_variance as mv;
use simopt::util::json::Value;
use simopt::util::prop::{check, Gen};

/// Random bounded LP: positive technology rows ⇒ bounded, origin-feasible.
fn random_lp(g: &mut Gen) -> LpProblem {
    let n = g.usize_in(1..6);
    let m = g.usize_in(1..5);
    let c: Vec<f64> = (0..n).map(|_| g.f64_in(-3.0..3.0)).collect();
    let a: Vec<f64> = (0..m * n).map(|_| g.f64_in(0.05..2.0)).collect();
    let b: Vec<f64> = (0..m).map(|_| g.f64_in(0.2..5.0)).collect();
    LpProblem::new(c, a, b)
}

#[test]
fn lp_optimum_feasible_and_beats_random_feasible_points() {
    check("lp optimal dominates sampled points", 150, random_lp, |p| {
        match lp::solve(p) {
            LpResult::Optimal { x, obj, .. } => {
                if !lp::is_feasible(p, &x, 1e-6) {
                    return false;
                }
                // scaled random points must never beat the optimum
                let mut g = Gen::new(p.n as u64 * 31 + p.m as u64);
                for _ in 0..10 {
                    let mut y: Vec<f64> =
                        (0..p.n).map(|_| g.f64_in(0.0..3.0)).collect();
                    let mut shrink: f64 = 1.0;
                    for r in 0..p.m {
                        let lhs: f64 =
                            (0..p.n).map(|j| p.a[r * p.n + j] * y[j]).sum();
                        if lhs > p.b[r] && lhs > 0.0 {
                            shrink = shrink.min(p.b[r] / lhs);
                        }
                    }
                    y.iter_mut().for_each(|v| *v *= shrink);
                    let oy: f64 =
                        p.c.iter().zip(&y).map(|(c, v)| c * v).sum();
                    if obj > oy + 1e-6 {
                        return false;
                    }
                }
                true
            }
            _ => false, // positive A, positive b: always optimal
        }
    });
}

#[test]
fn lp_scaling_invariance() {
    // Scaling the objective scales the optimum value, not the vertex.
    check("lp objective scaling", 100, random_lp, |p| {
        let r1 = lp::solve(p);
        let scaled = LpProblem::new(
            p.c.iter().map(|c| c * 2.0).collect(),
            p.a.clone(),
            p.b.clone(),
        );
        let r2 = lp::solve(&scaled);
        match (r1, r2) {
            (LpResult::Optimal { obj: o1, .. }, LpResult::Optimal { obj: o2, .. }) => {
                (2.0 * o1 - o2).abs() < 1e-6 * (1.0 + o1.abs())
            }
            _ => false,
        }
    });
}

#[test]
fn analytic_simplex_lmo_equals_lp_solution() {
    check("analytic LMO == LP over capped simplex", 120,
        |g| g.vec_f32(1..24, -2.0..2.0),
        |grad| {
            // LP formulation: min g·s, s ≥ 0, Σ s ≤ 1
            let n = grad.len();
            let p = LpProblem::new(
                grad.iter().map(|&v| v as f64).collect(),
                vec![1.0; n],
                vec![1.0],
            );
            let lp_obj = match lp::solve(&p) {
                LpResult::Optimal { obj, .. } => obj,
                _ => return false,
            };
            let analytic = match mv::simplex_lmo(grad) {
                Some(j) => grad[j] as f64,
                None => 0.0,
            };
            (lp_obj - analytic).abs() < 1e-6
        });
}

#[test]
fn fw_iterates_stay_in_simplex_under_any_vertex_sequence() {
    check("FW feasibility closed under updates", 150,
        |g| {
            let d = g.usize_in(2..16);
            let steps: Vec<(Option<usize>, f32)> = (0..g.usize_in(1..30))
                .map(|_| {
                    let v = if g.bool() { Some(g.usize_in(0..d)) } else { None };
                    (v, g.f32_in(0.0..1.0))
                })
                .collect();
            (d, steps)
        },
        |(d, steps)| {
            let mut w = vec![1.0f32 / *d as f32; *d];
            for &(v, gamma) in steps {
                mv::fw_vertex_update(&mut w, v, gamma);
                if !mv::in_simplex(&w, 1e-5) {
                    return false;
                }
            }
            true
        });
}

#[test]
fn stream_tree_paths_never_collide() {
    check("derived stream keys distinct across paths", 100,
        |g| {
            let seed = g.u64_in(0..1_000_000);
            let a = vec![g.u64_in(0..50), g.u64_in(0..50)];
            let b = vec![g.u64_in(0..50), g.u64_in(0..50)];
            (seed, a, b)
        },
        |(seed, a, b)| {
            let t = StreamTree::new(*seed);
            if a == b {
                t.derive(a) == t.derive(b)
            } else {
                t.derive(a) != t.derive(b)
            }
        });
}

#[test]
fn philox_jump_ahead_consistency() {
    check("philox block addressing", 100,
        |g| (g.u64_in(0..u64::MAX / 2), g.usize_in(0..64)),
        |&(seed, blocks)| {
            let mut seq = Philox::new(seed);
            for _ in 0..blocks * 4 {
                seq.next_u32();
            }
            let mut jumped = Philox::at_block(seed, blocks as u64);
            seq.next_u32() == jumped.next_u32()
        });
}

#[test]
fn json_roundtrip_arbitrary_trees() {
    check("json parse∘print == id", 150,
        |g| random_json(g, 0),
        |v| {
            let text = v.to_string_pretty();
            match Value::parse(&text) {
                Ok(back) => back == *v,
                Err(_) => false,
            }
        });
}

fn random_json(g: &mut Gen, depth: usize) -> Value {
    let choice = if depth >= 3 { g.usize_in(0..4) } else { g.usize_in(0..6) };
    match choice {
        0 => Value::Null,
        1 => Value::Bool(g.bool()),
        2 => Value::Num((g.f64_in(-1e6..1e6) * 100.0).round() / 100.0),
        3 => Value::Str(
            (0..g.usize_in(0..12))
                .map(|_| char::from(g.usize_in(32..127) as u8))
                .collect(),
        ),
        4 => Value::Arr((0..g.usize_in(0..4))
            .map(|_| random_json(g, depth + 1))
            .collect()),
        _ => Value::Obj((0..g.usize_in(0..4))
            .map(|i| (format!("k{}", i), random_json(g, depth + 1)))
            .collect()),
    }
}

#[test]
fn rse_is_scale_invariant() {
    check("RSE(ay, ay*) == RSE(y, y*)", 200,
        |g| (g.f64_in(0.1..100.0), g.f64_in(0.1..100.0), g.f64_in(0.1..10.0)),
        |&(y, ystar, a)| {
            let r1 = simopt::util::stats::rse_percent(y, ystar);
            let r2 = simopt::util::stats::rse_percent(a * y, a * ystar);
            (r1 - r2).abs() < 1e-9 * (1.0 + r1.abs())
        });
}

#[test]
fn correction_memory_count_bounded() {
    check("memory never exceeds capacity", 100,
        |g| {
            let cap = g.usize_in(1..6);
            let n = g.usize_in(1..8);
            let pushes = g.usize_in(0..20);
            (cap, n, pushes)
        },
        |&(cap, n, pushes)| {
            let mut mem = simopt::tasks::CorrectionMemory::new(cap, n);
            let mut g = Gen::new((cap * 31 + n) as u64);
            for _ in 0..pushes {
                let s: Vec<f32> = (0..n).map(|_| g.f32_in(-1.0..1.0)).collect();
                let y: Vec<f32> = s.iter().map(|&v| v * 1.3 + 0.01).collect();
                mem.push(&s, &y);
                if mem.count > cap {
                    return false;
                }
            }
            true
        });
}

#[test]
fn experiment_spec_wire_roundtrip_is_identity() {
    // The protocol's and the service cache's foundation (DESIGN.md §14):
    // parse∘render over the canonical wire encoding is identity — same
    // compact rendering, same spec_hash — for random specs drawn across
    // every registered task, backend, exec mode, and legal shard count.
    use simopt::backend::HessianMode;
    use simopt::config::{BackendKind, ExecMode, TaskKind};
    use simopt::coordinator::ExperimentSpec;

    let kinds = TaskKind::all();
    let backends =
        [BackendKind::Native, BackendKind::NativePar, BackendKind::Xla];
    check("spec parse∘render identity", 300,
        move |g| {
            let task = *g.pick(&kinds);
            let reps = g.usize_in(1..9);
            let mut spec =
                ExperimentSpec::new(task, *g.pick(&backends))
                    .size(g.usize_in(1..4096))
                    .epochs(g.usize_in(1..500))
                    .replications(reps)
                    // exercise the full u64 range: seeds ride the wire as
                    // decimal strings precisely because f64 JSON numbers
                    // would truncate past 2^53
                    .seed(g.u64_in(0..u64::MAX));
            spec.exec = match g.usize_in(0..4) {
                0 => ExecMode::Auto,
                1 => ExecMode::Sequential,
                _ => ExecMode::Batched { shards: g.usize_in(1..reps + 1) },
            };
            if g.bool() {
                spec.hessian_mode = HessianMode::TwoLoop;
            }
            if g.bool() {
                spec = spec.results_dir(
                    &format!("/tmp/rd-{}", g.usize_in(0..1000)));
            }
            spec.track_every = g.usize_in(1..50);
            spec.params.samples = g.usize_in(1..256);
            spec.params.m_inner = g.usize_in(1..64);
            spec.params.batch = g.usize_in(0..128);
            spec.params.hbatch = g.usize_in(0..512);
            spec.params.memory = g.usize_in(0..32);
            spec.params.l_every = g.usize_in(0..16);
            spec.params.beta = g.f32_in(0.0..8.0);
            spec.params.resources = g.usize_in(0..32);
            spec.params.tightness = g.f32_in(0.0..1.0);
            spec
        },
        |spec| {
            let text = spec.to_json().to_string_compact();
            let back = match ExperimentSpec::from_json(
                &Value::parse(&text).unwrap()) {
                Ok(b) => b,
                Err(_) => return false,
            };
            // identity: byte-identical re-rendering, equal cache keys, and
            // the lossy-prone fields survive exactly
            back.to_json().to_string_compact() == text
                && back.spec_hash() == spec.spec_hash()
                && back.seed == spec.seed
                && back.exec == spec.exec
                && back.params.beta.to_bits() == spec.params.beta.to_bits()
                && back.params.tightness.to_bits()
                    == spec.params.tightness.to_bits()
                && back.results_dir == spec.results_dir
        });
}
