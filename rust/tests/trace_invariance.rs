//! The tracing/metrics invariance suite (DESIGN.md §18).
//!
//! Three contracts:
//! * **Invariance** — a server running with `--trace-out` hands back
//!   results bitwise-identical to direct untraced `Coordinator::run`s
//!   of the same specs, for EVERY registered task on every execution
//!   plan: span recording happens strictly outside the timed regions,
//!   so observing a run cannot perturb it.
//! * **Metrics** — the v2-only `metrics` verb reports exactly the
//!   counters a scripted conversation implies (N submits, one fast-path
//!   cache hit, `busy` on a capacity-0 queue), and a v1 frame asking
//!   for it gets a typed error, not data.
//! * **Trace structure** — the `--trace-out` JSONL is well-formed, the
//!   conversation's spans (admission → cache check → queue wait →
//!   execute → relay, one `epoch` per progress frame) appear exactly
//!   once each, nest inside the `request` parent, and sum to the
//!   request's wall-clock within tolerance.

use std::io::BufReader;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::thread::JoinHandle;

use simopt::config::ExecMode;
use simopt::coordinator::{Coordinator, ExperimentSpec};
use simopt::service::protocol::{read_frame, write_frame};
use simopt::service::{Client, Response, Server, ServerConfig, ServerStats};
use simopt::tasks::registry;
use simopt::util::json::Value;
use simopt::util::trace::now_us;

fn temp_path(tag: &str, ext: &str) -> PathBuf {
    static N: AtomicU64 = AtomicU64::new(0);
    std::env::temp_dir().join(format!(
        "simopt-{}-{}-{}.{}",
        tag,
        std::process::id(),
        N.fetch_add(1, Ordering::Relaxed),
        ext
    ))
}

fn results_dir() -> String {
    std::env::temp_dir()
        .join("simopt_trace_invariance")
        .to_string_lossy()
        .into_owned()
}

/// Bind + run an in-process server writing spans to a fresh trace file;
/// the socket exists when this returns.
fn spawn_traced_server(tag: &str, queue: usize)
    -> (PathBuf, PathBuf, JoinHandle<ServerStats>) {
    let socket = temp_path(tag, "sock");
    let trace_out = temp_path(tag, "jsonl");
    let _ = std::fs::remove_file(&trace_out); // Tracer::to_file appends
    let server = Server::bind(ServerConfig {
        socket: socket.clone(),
        artifact_dir: "artifacts".into(),
        results_dir: results_dir(),
        workers: 1,
        queue_capacity: queue,
        cache_capacity: 64,
        trace_out: Some(trace_out.clone()),
    })
    .unwrap();
    let handle = std::thread::spawn(move || server.run().unwrap());
    (socket, trace_out, handle)
}

/// Shut down and JOIN the server: `Server::run` joins its handler
/// threads before returning, so once this returns every span of every
/// conversation has been flushed to the trace file — the suite reads
/// the JSONL only after this barrier.
fn shut_down(socket: &PathBuf, handle: JoinHandle<ServerStats>)
    -> ServerStats {
    Client::connect(socket).unwrap().shutdown().unwrap();
    handle.join().unwrap()
}

fn submit(socket: &PathBuf, spec: &ExperimentSpec) -> Response {
    Client::connect(socket)
        .unwrap()
        .session(spec, false)
        .unwrap()
        .finish()
        .unwrap()
}

/// One parsed span line of the Chrome-trace JSONL.
#[derive(Debug, Clone)]
struct SpanLine {
    name: String,
    trace: String,
    ts: f64,
    dur: f64,
}

fn parse_trace_file(path: &PathBuf) -> Vec<SpanLine> {
    let text = std::fs::read_to_string(path).unwrap();
    text.lines()
        .map(|line| {
            let v = Value::parse(line)
                .unwrap_or_else(|e| panic!("bad JSONL line {:?}: {}",
                                           line, e));
            // Chrome complete-event grammar, every line
            assert_eq!(v.get("ph").and_then(Value::as_str), Some("X"),
                       "{}", line);
            assert_eq!(v.get("cat").and_then(Value::as_str),
                       Some("simopt"), "{}", line);
            SpanLine {
                name: v.get("name").and_then(Value::as_str)
                    .expect("span name").to_string(),
                trace: v.get("args").and_then(|a| a.get("trace"))
                    .and_then(Value::as_str)
                    .expect("args.trace").to_string(),
                ts: v.get("ts").and_then(Value::as_f64).expect("ts"),
                dur: v.get("dur").and_then(Value::as_f64).expect("dur"),
            }
        })
        .collect()
}

fn one<'a>(spans: &'a [SpanLine], name: &str) -> &'a SpanLine {
    let hits: Vec<&SpanLine> =
        spans.iter().filter(|s| s.name == name).collect();
    assert_eq!(hits.len(), 1, "span '{}' must appear exactly once, got \
                {:?}", name, spans);
    hits[0]
}

#[test]
fn traced_served_results_are_bitwise_identical_to_untraced_direct_runs() {
    let (socket, trace_out, handle) = spawn_traced_server("inv", 8);
    let mut direct = Coordinator::new("artifacts", &results_dir()).unwrap();
    for task in registry::all() {
        for exec in [ExecMode::Sequential, ExecMode::Batched { shards: 1 },
                     ExecMode::Batched { shards: 2 }] {
            let mut spec = task.smoke_spec();
            spec.reps = 3; // makes shards=2 an uneven 2+1 split
            spec.exec = exec;
            let want = direct.run(&spec).unwrap();
            match submit(&socket, &spec) {
                Response::Completed { cache_hit, result, .. } => {
                    assert!(!cache_hit);
                    // the deterministic payloads are byte-identical:
                    // tracing recorded spans but perturbed nothing
                    assert_eq!(
                        result.canonical_json().to_string_pretty(),
                        want.canonical_json().to_string_pretty(),
                        "task {} exec {:?}", task.name(), exec
                    );
                    for (a, b) in want.reps.iter().zip(&result.reps) {
                        assert_eq!(a.objs, b.objs,
                                   "task {} exec {:?}", task.name(), exec);
                    }
                }
                other => panic!("task {} exec {:?}: expected a result, \
                                 got {:?}", task.name(), exec, other),
            }
        }
    }
    let stats = shut_down(&socket, handle);
    assert_eq!(stats.executed, (registry::all().count() * 3) as u64);
    // every traced conversation recorded a full, distinct span chain
    let spans = parse_trace_file(&trace_out);
    let requests: Vec<&SpanLine> =
        spans.iter().filter(|s| s.name == "request").collect();
    assert_eq!(requests.len(), registry::all().count() * 3 + 1,
               "one request span per submit + one for the shutdown");
    for req in &requests {
        assert!(req.trace.len() == 16
                    && req.trace.chars().all(|c| c.is_ascii_hexdigit()),
                "trace ids are 16 hex digits, got {:?}", req.trace);
    }
    let _ = std::fs::remove_file(&trace_out);
}

#[test]
fn metrics_verb_reports_the_scripted_conversation() {
    let (socket, trace_out, handle) = spawn_traced_server("met", 8);
    let mut spec_a = registry::all().next().unwrap().smoke_spec();
    spec_a.seed = 101;
    let mut spec_b = spec_a.clone();
    spec_b.seed = 202;
    // two distinct submits execute; resubmitting the first answers from
    // the handler's fast-path cache probe without queueing
    for spec in [&spec_a, &spec_b] {
        match submit(&socket, spec) {
            Response::Completed { cache_hit, .. } => assert!(!cache_hit),
            other => panic!("expected a result, got {:?}", other),
        }
    }
    match submit(&socket, &spec_a) {
        Response::Completed { cache_hit, .. } => assert!(cache_hit),
        other => panic!("expected a cached result, got {:?}", other),
    }
    let snap = Client::connect(&socket).unwrap().metrics().unwrap();
    assert_eq!(snap.counter("submits_total"), Some(3));
    assert_eq!(snap.counter("runs_executed_total"), Some(2));
    assert_eq!(snap.counter("cache_hits_total"), Some(1));
    assert_eq!(snap.counter("cache_misses_total"), Some(2));
    assert_eq!(snap.counter("busy_rejections_total"), Some(0));
    // one terminal frame relayed per executed (non-streaming) submit;
    // the fast-path hit is a handler-local write, not a relay
    assert_eq!(snap.counter("frames_relayed_total"), Some(2));
    assert_eq!(snap.counter("frozen_rows_total"), Some(0),
               "no budget on these specs");
    assert_eq!(snap.gauge("queue_depth"), Some(0), "drained");
    assert!(snap.gauge("queue_depth_high_water").unwrap() >= 1);
    assert_eq!(snap.gauge("cache_entries"), Some(2));
    let qw = snap.histogram("queue_wait_seconds").unwrap();
    assert_eq!(qw.count, 2, "one measured wait per popped job");
    assert_eq!(qw.counts.iter().sum::<u64>(), qw.count);
    let rl = snap.histogram("run_latency_seconds").unwrap();
    assert_eq!(rl.count, 2, "one latency per executed run");
    assert!(rl.sum_s > 0.0);
    // per-phase totals ride the snapshot (DESIGN.md §15)
    assert!(!snap.per_phase.is_empty());
    // the Prometheus rendering exposes the same numbers
    let prom = snap.to_prometheus();
    assert!(prom.contains("simopt_runs_executed_total 2"), "{}", prom);
    assert!(prom.contains("simopt_queue_wait_seconds_count 2"), "{}", prom);
    shut_down(&socket, handle);
    let _ = std::fs::remove_file(&trace_out);
}

#[test]
fn capacity_zero_counts_busy_rejections_and_v1_metrics_is_refused() {
    let (socket, trace_out, handle) = spawn_traced_server("busy", 0);
    let spec = registry::all().next().unwrap().smoke_spec();
    match submit(&socket, &spec) {
        Response::Busy { capacity: 0 } => {}
        other => panic!("expected busy, got {:?}", other),
    }
    let snap = Client::connect(&socket).unwrap().metrics().unwrap();
    assert_eq!(snap.counter("submits_total"), Some(1));
    assert_eq!(snap.counter("busy_rejections_total"), Some(1));
    assert_eq!(snap.counter("cache_misses_total"), Some(1),
               "the fast path probed the cache before the queue bounced");
    assert_eq!(snap.counter("runs_executed_total"), Some(0));
    // a raw v1 frame asking for metrics gets a typed error — the v1
    // grammar is frozen (DESIGN.md §18)
    let stream =
        std::os::unix::net::UnixStream::connect(&socket).unwrap();
    let mut writer = stream.try_clone().unwrap();
    let mut reader = BufReader::new(stream);
    write_frame(&mut writer,
                &Value::parse(r#"{"v":1,"type":"metrics"}"#).unwrap())
        .unwrap();
    let answer = read_frame(&mut reader).unwrap().unwrap();
    assert_eq!(answer.get("type").and_then(Value::as_str), Some("error"));
    assert_eq!(answer.get("v").and_then(Value::as_f64), Some(1.0));
    let msg = answer.get("error").and_then(Value::as_str).unwrap();
    assert!(msg.contains("protocol v2"), "{}", msg);
    shut_down(&socket, handle);
    let _ = std::fs::remove_file(&trace_out);
}

#[test]
fn streaming_spans_chain_nest_and_sum_to_the_observed_wall_clock() {
    let (socket, trace_out, handle) = spawn_traced_server("span", 8);
    let mut spec = registry::all().next().unwrap().smoke_spec();
    spec.seed = 4242; // unique — must execute, not hit another test's cache
    spec.exec = ExecMode::Batched { shards: 1 };
    let wall_start = now_us();
    let mut client = Client::connect(&socket).unwrap();
    let mut session = client.session(&spec, true).unwrap();
    let mut progress_frames = 0usize;
    let terminal = loop {
        match session.next_event().unwrap() {
            Some(Response::Queued { .. }) => {}
            Some(Response::Progress(_)) => progress_frames += 1,
            Some(t) => break t,
            None => panic!("session ended without a terminal frame"),
        }
    };
    let wall_us = (now_us() - wall_start) as f64;
    assert!(matches!(terminal, Response::Completed { .. }),
            "{:?}", terminal);
    assert!(progress_frames >= 1, "a streaming submit must progress");
    // every v2 frame carried the conversation's trace id
    let trace = session.trace().expect("v2 frames carry a trace stamp");
    drop(session);
    shut_down(&socket, handle); // span-flush barrier (see shut_down)
    let all = parse_trace_file(&trace_out);
    let spans: Vec<SpanLine> = all.iter()
        .filter(|s| s.trace == trace.as_hex())
        .cloned()
        .collect();
    // the five life-cycle spans appear exactly once each…
    let request = one(&spans, "request");
    let stages = ["admission", "cache_check", "queue_wait", "execute",
                  "relay"];
    let mut stage_sum = 0.0;
    for name in stages {
        let sp = one(&spans, name);
        // …nested inside the request parent…
        assert!(sp.ts >= request.ts
                    && sp.ts + sp.dur <= request.ts + request.dur,
                "{} [{}, {}] outside request [{}, {}]",
                name, sp.ts, sp.ts + sp.dur,
                request.ts, request.ts + request.dur);
        stage_sum += sp.dur;
    }
    // …with one epoch span per relayed progress frame, nested in execute
    let execute = one(&spans, "execute");
    let epochs: Vec<&SpanLine> =
        spans.iter().filter(|s| s.name == "epoch").collect();
    assert_eq!(epochs.len(), progress_frames);
    for ep in &epochs {
        assert!(ep.ts >= execute.ts
                    && ep.ts + ep.dur <= execute.ts + execute.dur,
                "epoch [{}, {}] outside execute [{}, {}]",
                ep.ts, ep.ts + ep.dur, execute.ts,
                execute.ts + execute.dur);
    }
    // the stage spans are disjoint and contiguous-by-construction, so
    // they sum to at most the request's duration, and account for it
    // within tolerance (scheduling gaps: channel handoff, thread wakes)
    assert!(stage_sum <= request.dur + 1.0,
            "stages sum {} > request {}", stage_sum, request.dur);
    let gap = request.dur - stage_sum;
    assert!(gap <= 0.10 * request.dur + 100_000.0,
            "unattributed gap {}µs of a {}µs request", gap, request.dur);
    // and the request span itself is bounded by the client-observed wall
    assert!(request.dur <= wall_us + 1_000.0,
            "request span {}µs exceeds observed wall {}µs",
            request.dur, wall_us);
    let _ = std::fs::remove_file(&trace_out);
}
