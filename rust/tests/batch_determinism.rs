//! Batched-vs-sequential equivalence (DESIGN.md §11): for every task, the
//! replication-batched engine and the per-replication path must produce
//! BIT-IDENTICAL iterates and objectives under the same seed, and distinct
//! replication streams must stay disjoint.  Randomized over
//! (seed, size, reps) via the in-tree property harness.

use simopt::backend::native::{NativeLr, NativeLrBatch, NativeMode};
use simopt::backend::{HessianMode, LrBackend, LrBatchBackend};
use simopt::config::{BackendKind, ExecMode, TaskKind};
use simopt::coordinator::{Coordinator, ExperimentSpec, RunResult};
use simopt::rng::{Philox, StreamTree};
use simopt::sim::ClassifyData;
use simopt::tasks::{BatchCorrectionMemory, CorrectionMemory};
use simopt::util::prop::{check, Gen};

fn results_dir() -> String {
    std::env::temp_dir()
        .join("simopt_batch_determinism")
        .to_string_lossy()
        .into_owned()
}

/// A CI-sized spec for the given cell (classification needs its own batch
/// parameters to finish quickly).
fn tiny_spec(task: TaskKind, size: usize, reps: usize, seed: u64)
    -> ExperimentSpec {
    let mut spec = ExperimentSpec::new(task, BackendKind::Native)
        .size(size)
        .replications(reps)
        .seed(seed);
    match task {
        TaskKind::Classification => {
            spec.params.iters = 25;
            spec.params.batch = 12;
            spec.params.hbatch = 24;
            spec.params.l_every = 4;
            spec.params.memory = 3;
            spec.track_every = 5;
        }
        _ => {
            spec.params.iters = 3;
            spec.params.m_inner = 3;
            spec.params.samples = 8;
        }
    }
    spec
}

fn run_mode(spec: &ExperimentSpec, exec: ExecMode) -> RunResult {
    let mut coord = Coordinator::new("artifacts", &results_dir()).unwrap();
    let mut spec = spec.clone();
    spec.exec = exec;
    coord.run(&spec).unwrap()
}

fn identical(a: &RunResult, b: &RunResult) -> bool {
    a.reps.len() == b.reps.len()
        && a.reps.iter().zip(&b.reps).all(|(ra, rb)| {
            ra.objs == rb.objs && ra.obj_iters == rb.obj_iters
        })
}

/// Draw a random (seed, size, reps) cell.
fn random_cell(g: &mut Gen) -> (u64, usize, usize) {
    (g.u64_in(0..10_000), 8 + 4 * g.usize_in(0..4), g.usize_in(2..5))
}

#[test]
fn mv_batched_equals_sequential_bitwise() {
    check("mv batched == sequential", 6, random_cell,
        |&(seed, size, reps)| {
            let spec = tiny_spec(TaskKind::MeanVariance, size, reps, seed);
            identical(&run_mode(&spec, ExecMode::Sequential),
                      &run_mode(&spec, ExecMode::Batched { shards: 1 }))
        });
}

#[test]
fn nv_batched_equals_sequential_bitwise() {
    check("nv batched == sequential", 4, random_cell,
        |&(seed, size, reps)| {
            let spec = tiny_spec(TaskKind::Newsvendor, size, reps, seed);
            identical(&run_mode(&spec, ExecMode::Sequential),
                      &run_mode(&spec, ExecMode::Batched { shards: 1 }))
        });
}

#[test]
fn lr_batched_equals_sequential_bitwise() {
    check("lr batched == sequential", 3, random_cell,
        |&(seed, size, reps)| {
            let spec = tiny_spec(TaskKind::Classification, size, reps, seed);
            identical(&run_mode(&spec, ExecMode::Sequential),
                      &run_mode(&spec, ExecMode::Batched { shards: 1 }))
        });
}

#[test]
fn cvar_batched_equals_sequential_bitwise() {
    // The fourth scenario registered through the task-registry plane
    // (DESIGN.md §12) inherits the same bitwise contract with zero changes
    // to this property.
    check("cvar batched == sequential", 6, random_cell,
        |&(seed, size, reps)| {
            let spec = tiny_spec(TaskKind::MeanCvar, size, reps, seed);
            identical(&run_mode(&spec, ExecMode::Sequential),
                      &run_mode(&spec, ExecMode::Batched { shards: 1 }))
        });
}

#[test]
fn batched_replication_streams_stay_disjoint() {
    // Within one batched run, every replication must follow its own
    // trajectory (pairwise-distinct objective traces), and the run must be
    // reproducible call-to-call.
    for task in TaskKind::all() {
        let spec = tiny_spec(task, 12, 4, 77);
        let a = run_mode(&spec, ExecMode::Batched { shards: 1 });
        for i in 0..a.reps.len() {
            for j in i + 1..a.reps.len() {
                assert_ne!(a.reps[i].objs, a.reps[j].objs,
                           "task {}: replications {} and {} collided",
                           task, i, j);
            }
        }
        let b = run_mode(&spec, ExecMode::Batched { shards: 1 });
        assert!(identical(&a, &b), "task {}: batched run not reproducible",
                task);
    }
}

// ---------------------------------------------------------------------------
// Padded-vs-ragged direction engine (DESIGN.md §11)
// ---------------------------------------------------------------------------

/// One padded-engine cell: seed, feature dim, replication count, correction
/// capacity, and a heterogeneous per-row push schedule.  Row 0 is pinned
/// empty and the last row pinned past capacity so every draw covers the
/// empty / partial / full / ring-wrapped spectrum at once.
#[derive(Debug)]
struct FillCell {
    seed: u64,
    n: usize,
    reps: usize,
    capacity: usize,
    fills: Vec<usize>,
}

fn random_fill_cell(g: &mut Gen) -> FillCell {
    let reps = g.usize_in(3..6);
    let capacity = g.usize_in(2..5);
    let mut fills: Vec<usize> =
        (0..reps).map(|_| g.usize_in(0..capacity + 3)).collect();
    fills[0] = 0; // always one empty row (plain-gradient fallback)
    fills[reps - 1] = capacity + 2; // always one ring-wrapped row
    FillCell {
        seed: g.u64_in(0..10_000),
        n: 6 + 2 * g.usize_in(0..4),
        reps,
        capacity,
        fills,
    }
}

/// Push the cell's schedule into both a `BatchCorrectionMemory` and
/// independent ragged `CorrectionMemory`s, asserting identical
/// accept/reject decisions.  Every third pair has negated curvature so
/// the rejection path is exercised on both sides.
fn fill_both(cell: &FillCell)
    -> Option<(BatchCorrectionMemory, Vec<CorrectionMemory>)> {
    let (n, reps) = (cell.n, cell.reps);
    let mut batch = BatchCorrectionMemory::new(reps, cell.capacity, n);
    let mut ragged: Vec<CorrectionMemory> =
        (0..reps).map(|_| CorrectionMemory::new(cell.capacity, n)).collect();
    let mut p = Philox::new(cell.seed ^ 0xD1CE);
    for r in 0..reps {
        for t in 0..cell.fills[r] {
            let s: Vec<f32> =
                (0..n).map(|_| p.uniform_f32(-0.5, 0.5)).collect();
            let y: Vec<f32> = if t % 3 == 2 {
                s.iter().map(|&v| -v).collect() // non-positive curvature
            } else {
                s.iter().map(|&v| 1.5 * v + 0.01).collect()
            };
            if batch.push_row(r, &s, &y) != ragged[r].push(&s, &y) {
                return None;
            }
        }
        if batch.count(r) != ragged[r].count {
            return None;
        }
    }
    Some((batch, ragged))
}

#[test]
fn padded_memory_matches_ragged_push_semantics_and_padding() {
    check("padded push == ragged push", 12, random_fill_cell, |cell| {
        let Some((batch, ragged)) = fill_both(cell) else { return false };
        let n = cell.n;
        for r in 0..cell.reps {
            let row = batch.row(r);
            let take = row.count * n;
            // identical valid pairs, oldest first…
            if row.s_mem[..take] != ragged[r].s_mem[..take]
                || row.y_mem[..take] != ragged[r].y_mem[..take] {
                return false;
            }
            // …and a partial row's padded tail stays exactly zero (the
            // batched artifact masks on the count, never on the values)
            if row.count < cell.capacity
                && !(row.s_mem[take..].iter().all(|&v| v == 0.0)
                     && row.y_mem[take..].iter().all(|&v| v == 0.0)) {
                return false;
            }
        }
        true
    });
}

#[test]
fn padded_direction_bitwise_matches_ragged_per_row() {
    // The tentpole property: ONE direction_batch call over the padded
    // panels must reproduce, bit for bit, what each replication's ragged
    // memory produces through the sequential backend — across empty,
    // partially filled, full, and ring-wrapped rows, in both Hessian
    // modes.  Inactive rows must be left untouched.
    check("padded direction == ragged direction", 8, random_fill_cell,
        |cell| {
            let Some((batch_mem, ragged)) = fill_both(cell) else {
                return false;
            };
            let (n, reps) = (cell.n, cell.reps);
            let data = ClassifyData::generate(&StreamTree::new(cell.seed), n);
            let mut p = Philox::new(cell.seed ^ 0x9A);
            let g: Vec<f32> =
                (0..reps * n).map(|_| p.uniform_f32(-1.0, 1.0)).collect();
            for mode in [HessianMode::Explicit, HessianMode::TwoLoop] {
                let mut batch = NativeLrBatch::new(&data, reps, 3, mode);
                let mut dirs = vec![f32::NAN; reps * n];
                batch.direction_batch(batch_mem.view(), &g, &mut dirs)
                    .unwrap();
                for r in 0..reps {
                    let got = &dirs[r * n..(r + 1) * n];
                    if batch_mem.is_active(r) {
                        let mut single = NativeLr::new(
                            &data, NativeMode::Sequential, mode);
                        let want = single
                            .direction(&ragged[r], &g[r * n..(r + 1) * n])
                            .unwrap();
                        if got != want.as_slice() {
                            return false;
                        }
                    } else if !got.iter().all(|v| v.is_nan()) {
                        return false; // empty row written unexpectedly
                    }
                }
            }
            true
        });
}

#[test]
fn auto_mode_matches_both_explicit_modes() {
    // Auto picks batched here (native, reps ≥ 2) — whatever it picks must
    // agree with both explicit modes.
    let spec = tiny_spec(TaskKind::MeanVariance, 16, 3, 5);
    let auto = run_mode(&spec, ExecMode::Auto);
    assert!(identical(&auto, &run_mode(&spec, ExecMode::Sequential)));
    assert!(identical(&auto, &run_mode(&spec, ExecMode::Batched { shards: 1 })));
}

// ---------------------------------------------------------------------------
// The shard-aware panel plane (DESIGN.md §13)
// ---------------------------------------------------------------------------

#[test]
fn sharded_batched_equals_unsharded_bitwise() {
    // The shard plane's refactor invariant, as a property over random
    // (seed, size, reps) cells for EVERY registered task: every legal
    // shard count 2..=R (which includes uneven R % S ≠ 0 splits for
    // R ≥ 3 and the one-row-per-shard extreme S = R) produces the exact
    // bits of the unsharded S = 1 panel.
    check("sharded == unsharded", 3, random_cell, |&(seed, size, reps)| {
        TaskKind::all().into_iter().all(|task| {
            let spec = tiny_spec(task, size, reps, seed);
            let unsharded =
                run_mode(&spec, ExecMode::Batched { shards: 1 });
            (2..=reps).all(|shards| {
                identical(&unsharded,
                          &run_mode(&spec, ExecMode::Batched { shards }))
            })
        })
    });
}

// ---------------------------------------------------------------------------
// The panel LMO (DESIGN.md §17)
// ---------------------------------------------------------------------------

/// One panel-LMO cell: an NV instance shape plus a replication count.
#[derive(Debug)]
struct LmoCell {
    seed: u64,
    d: usize,
    m: usize,
    reps: usize,
}

fn random_lmo_cell(g: &mut Gen) -> LmoCell {
    LmoCell {
        seed: g.u64_in(0..10_000),
        d: 8 + 4 * g.usize_in(0..5),
        m: 1 + g.usize_in(0..4),
        reps: g.usize_in(2..6),
    }
}

#[test]
fn panel_lmo_bitwise_matches_per_row_solves() {
    // The tentpole property: one solve_panel_into call (shared-A seed +
    // pool fan-out) must reproduce, bit for bit, R independent
    // NvLmo::solve_into calls — over random instances, random mixed-sign
    // gradient panels, EVERY thread count 1..=R+1 (uneven chunks and the
    // degenerate threads > R case included), and repeated steps through
    // the same warm seed.
    use simopt::lp::PanelWorkspace;
    use simopt::sim::NewsvendorInstance;
    use simopt::tasks::NvLmo;
    check("panel lmo == per-row lmo", 6, random_lmo_cell, |cell| {
        let (d, reps) = (cell.d, cell.reps);
        let inst = NewsvendorInstance::generate(
            &StreamTree::new(cell.seed), d, cell.m, 0.6);
        let mut p = Philox::new(cell.seed ^ 0x1310);
        let steps: Vec<Vec<f32>> = (0..3)
            .map(|_| {
                (0..reps * d).map(|_| p.uniform_f32(-3.0, 2.0)).collect()
            })
            .collect();
        // reference: fresh sequential rows per step
        let want: Vec<Vec<f32>> = steps
            .iter()
            .map(|g| {
                let mut out = vec![0.0f32; reps * d];
                for i in 0..reps {
                    NvLmo::new(&inst)
                        .solve_into(&g[i * d..(i + 1) * d],
                                    &mut out[i * d..(i + 1) * d])
                        .unwrap();
                }
                out
            })
            .collect();
        (1..=reps + 1).all(|threads| {
            let mut lmos: Vec<NvLmo> =
                (0..reps).map(|_| NvLmo::new(&inst)).collect();
            let mut seed = PanelWorkspace::new();
            let mut verts = vec![0.0f32; reps * d];
            steps.iter().zip(&want).all(|(g, want_step)| {
                NvLmo::solve_panel_into(&mut lmos, &mut seed, g, &mut verts,
                                        threads)
                    .unwrap();
                verts
                    .iter()
                    .zip(want_step)
                    .all(|(a, b)| a.to_bits() == b.to_bits())
            })
        })
    });
}

#[test]
fn nv_panel_driver_bitwise_for_every_shard_and_thread_count() {
    // Driver-level closure of the same contract: the batched NV run —
    // panel LMO riding a sharded plane — stays bit-identical to the
    // sequential driver for S ∈ {1, 2, 3} × threads ∈ {1, 2, 3}.
    use simopt::backend::native::{NativeNv, NativeNvBatch};
    use simopt::backend::plane::ShardedBatch;
    use simopt::opt::{run_nv, run_nv_batch};
    use simopt::sim::NewsvendorInstance;
    use simopt::tasks::NvLmo;
    let (d, m, reps, epochs, m_inner, samples) = (10usize, 2usize, 5usize,
                                                  3usize, 3usize, 8usize);
    let root = StreamTree::new(61);
    let inst = NewsvendorInstance::generate(&root, d, m, 0.6);
    let x0 = inst.feasible_start();
    let trees: Vec<StreamTree> =
        (0..reps).map(|r| root.subtree(&[1000 + r as u64])).collect();

    let mut seq = Vec::new();
    for tree in &trees {
        let mut single = NativeNv::new(inst.clone(), samples,
                                       NativeMode::Sequential);
        let mut lmo = NvLmo::new(&inst);
        let (x, _) = run_nv(&mut single, &mut lmo, x0.clone(), epochs,
                            m_inner, tree)
            .unwrap();
        seq.extend_from_slice(&x);
    }

    for shards in [1usize, 2, 3] {
        for threads in [1usize, 2, 3] {
            let mut backend = ShardedBatch::pooled(
                reps, shards, d, threads, |rows| {
                    Ok(NativeNvBatch::new(&inst, samples, rows.len(), 1))
                })
                .unwrap();
            let mut lmos: Vec<NvLmo> =
                (0..reps).map(|_| NvLmo::new(&inst)).collect();
            let (panel, _) = run_nv_batch(&mut backend, &mut lmos, &x0,
                                          epochs, m_inner, &trees, threads)
                .unwrap();
            assert_eq!(panel.len(), seq.len());
            for (pos, (a, b)) in panel.iter().zip(&seq).enumerate() {
                assert_eq!(a.to_bits(), b.to_bits(),
                           "S={} threads={} pos={}", shards, threads, pos);
            }
        }
    }
}

#[test]
fn sharded_equals_sequential_for_every_task() {
    // The acceptance triangle, pinned (not randomized): R = 5 with
    // S ∈ {1, 2, 5} covers the unsharded panel, an uneven 3+2 split, and
    // one row per shard — each bit-identical to `--exec seq`.
    let (reps, shard_counts) = (5usize, [1usize, 2, 5]);
    for task in TaskKind::all() {
        let spec = tiny_spec(task, 12, reps, 31);
        let seq = run_mode(&spec, ExecMode::Sequential);
        for shards in shard_counts {
            let sharded = run_mode(&spec, ExecMode::Batched { shards });
            assert!(sharded.batched);
            assert_eq!(sharded.shards, shards, "task {}", task);
            assert!(identical(&seq, &sharded),
                    "task {}: S={} diverged from sequential", task, shards);
        }
    }
}
