//! Batched-vs-sequential equivalence (DESIGN.md §11): for every task, the
//! replication-batched engine and the per-replication path must produce
//! BIT-IDENTICAL iterates and objectives under the same seed, and distinct
//! replication streams must stay disjoint.  Randomized over
//! (seed, size, reps) via the in-tree property harness.

use simopt::config::{BackendKind, ExecMode, TaskKind};
use simopt::coordinator::{Coordinator, ExperimentSpec, RunResult};
use simopt::util::prop::{check, Gen};

fn results_dir() -> String {
    std::env::temp_dir()
        .join("simopt_batch_determinism")
        .to_string_lossy()
        .into_owned()
}

/// A CI-sized spec for the given cell (classification needs its own batch
/// parameters to finish quickly).
fn tiny_spec(task: TaskKind, size: usize, reps: usize, seed: u64)
    -> ExperimentSpec {
    let mut spec = ExperimentSpec::new(task, BackendKind::Native)
        .size(size)
        .replications(reps)
        .seed(seed);
    match task {
        TaskKind::Classification => {
            spec.params.iters = 25;
            spec.params.batch = 12;
            spec.params.hbatch = 24;
            spec.params.l_every = 4;
            spec.params.memory = 3;
            spec.track_every = 5;
        }
        _ => {
            spec.params.iters = 3;
            spec.params.m_inner = 3;
            spec.params.samples = 8;
        }
    }
    spec
}

fn run_mode(spec: &ExperimentSpec, exec: ExecMode) -> RunResult {
    let mut coord = Coordinator::new("artifacts", &results_dir()).unwrap();
    let mut spec = spec.clone();
    spec.exec = exec;
    coord.run(&spec).unwrap()
}

fn identical(a: &RunResult, b: &RunResult) -> bool {
    a.reps.len() == b.reps.len()
        && a.reps.iter().zip(&b.reps).all(|(ra, rb)| {
            ra.objs == rb.objs && ra.obj_iters == rb.obj_iters
        })
}

/// Draw a random (seed, size, reps) cell.
fn random_cell(g: &mut Gen) -> (u64, usize, usize) {
    (g.u64_in(0..10_000), 8 + 4 * g.usize_in(0..4), g.usize_in(2..5))
}

#[test]
fn mv_batched_equals_sequential_bitwise() {
    check("mv batched == sequential", 6, random_cell,
        |&(seed, size, reps)| {
            let spec = tiny_spec(TaskKind::MeanVariance, size, reps, seed);
            identical(&run_mode(&spec, ExecMode::Sequential),
                      &run_mode(&spec, ExecMode::Batched))
        });
}

#[test]
fn nv_batched_equals_sequential_bitwise() {
    check("nv batched == sequential", 4, random_cell,
        |&(seed, size, reps)| {
            let spec = tiny_spec(TaskKind::Newsvendor, size, reps, seed);
            identical(&run_mode(&spec, ExecMode::Sequential),
                      &run_mode(&spec, ExecMode::Batched))
        });
}

#[test]
fn lr_batched_equals_sequential_bitwise() {
    check("lr batched == sequential", 3, random_cell,
        |&(seed, size, reps)| {
            let spec = tiny_spec(TaskKind::Classification, size, reps, seed);
            identical(&run_mode(&spec, ExecMode::Sequential),
                      &run_mode(&spec, ExecMode::Batched))
        });
}

#[test]
fn batched_replication_streams_stay_disjoint() {
    // Within one batched run, every replication must follow its own
    // trajectory (pairwise-distinct objective traces), and the run must be
    // reproducible call-to-call.
    for task in TaskKind::all() {
        let spec = tiny_spec(task, 12, 4, 77);
        let a = run_mode(&spec, ExecMode::Batched);
        for i in 0..a.reps.len() {
            for j in i + 1..a.reps.len() {
                assert_ne!(a.reps[i].objs, a.reps[j].objs,
                           "task {}: replications {} and {} collided",
                           task, i, j);
            }
        }
        let b = run_mode(&spec, ExecMode::Batched);
        assert!(identical(&a, &b), "task {}: batched run not reproducible",
                task);
    }
}

#[test]
fn auto_mode_matches_both_explicit_modes() {
    // Auto picks batched here (native, reps ≥ 2) — whatever it picks must
    // agree with both explicit modes.
    let spec = tiny_spec(TaskKind::MeanVariance, 16, 3, 5);
    let auto = run_mode(&spec, ExecMode::Auto);
    assert!(identical(&auto, &run_mode(&spec, ExecMode::Sequential)));
    assert!(identical(&auto, &run_mode(&spec, ExecMode::Batched)));
}
