//! End-to-end integration: full optimization runs through the coordinator on
//! both backends, checking convergence quality and cross-backend agreement —
//! the Table-2 "same accuracy" claim at test scale.

use simopt::backend::HessianMode;
use simopt::config::{BackendKind, TaskKind};
use simopt::coordinator::{Coordinator, ExperimentSpec};

fn artifacts_built() -> bool {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        return false;
    }
    // also requires a real PJRT runtime (not the in-tree `xla` stub)
    match simopt::runtime::Engine::new("artifacts") {
        Ok(_) => true,
        Err(e) => {
            eprintln!("[skip] PJRT engine unavailable: {:#}", e);
            false
        }
    }
}

fn results_dir() -> String {
    let dir = std::env::temp_dir().join("simopt_e2e_results");
    dir.to_string_lossy().into_owned()
}

#[test]
fn mv_both_backends_converge_to_matching_objectives() {
    if !artifacts_built() {
        eprintln!("[skip] artifacts not built");
        return;
    }
    let mut coord = Coordinator::new("artifacts", &results_dir()).unwrap();
    let mut results = Vec::new();
    for backend in [BackendKind::Native, BackendKind::Xla] {
        let spec = ExperimentSpec::new(TaskKind::MeanVariance, backend)
            .size(128)
            .epochs(10)
            .replications(3)
            .seed(101);
        results.push(coord.run(&spec).unwrap());
    }
    let native_obj = results[0].final_obj_stats();
    let xla_obj = results[1].final_obj_stats();
    // the paper's Table-2 claim: same algorithm, same accuracy — the ±2σ
    // bands must overlap
    let (nlo, nhi) = native_obj.band2();
    let (xlo, xhi) = xla_obj.band2();
    assert!(
        nlo <= xhi && xlo <= nhi,
        "objective bands disjoint: native [{}, {}] vs xla [{}, {}]",
        nlo, nhi, xlo, xhi
    );
}

#[test]
fn nv_both_backends_converge_to_matching_cost() {
    if !artifacts_built() {
        eprintln!("[skip] artifacts not built");
        return;
    }
    let mut coord = Coordinator::new("artifacts", &results_dir()).unwrap();
    let mut finals = Vec::new();
    for backend in [BackendKind::Native, BackendKind::Xla] {
        let spec = ExperimentSpec::new(TaskKind::Newsvendor, backend)
            .size(256)
            .epochs(8)
            .replications(3)
            .seed(102);
        let res = coord.run(&spec).unwrap();
        finals.push(res.final_obj_stats());
    }
    let rel = (finals[0].mean() - finals[1].mean()).abs() / finals[0].mean();
    assert!(rel < 0.03, "final costs diverge by {:.1}%", rel * 100.0);
}

#[test]
fn lr_both_backends_identical_under_crn() {
    // Classification batches are gathered host-side, so with CRN both arms
    // run numerically near-identical iterations.
    if !artifacts_built() {
        eprintln!("[skip] artifacts not built");
        return;
    }
    let mut coord = Coordinator::new("artifacts", &results_dir()).unwrap();
    let mut traces = Vec::new();
    for backend in [BackendKind::Native, BackendKind::Xla] {
        let spec = ExperimentSpec::new(TaskKind::Classification, backend)
            .size(64)
            .epochs(60)
            .replications(2)
            .seed(103);
        let res = coord.run(&spec).unwrap();
        traces.push(res.reps[0].objs.clone());
    }
    assert_eq!(traces[0].len(), traces[1].len());
    for (a, b) in traces[0].iter().zip(&traces[1]) {
        assert!((a - b).abs() < 1e-3, "traces diverge: {} vs {}", a, b);
    }
}

#[test]
fn rse_trace_decreases_like_table2() {
    if !artifacts_built() {
        eprintln!("[skip] artifacts not built");
        return;
    }
    let mut coord = Coordinator::new("artifacts", &results_dir()).unwrap();
    let spec = ExperimentSpec::new(TaskKind::MeanVariance, BackendKind::Xla)
        .size(128)
        .epochs(20)
        .replications(3)
        .seed(104);
    let res = coord.run(&spec).unwrap();
    let cps = res.rse_checkpoints(&[0.1, 0.5, 1.0]);
    assert_eq!(cps.len(), 3);
    // RSE decreases towards 0 at the final checkpoint (definitionally)
    assert!(cps[2].2 < 1e-9);
    assert!(cps[0].2 >= cps[1].2,
            "RSE must decay: {:?}", cps);
}

#[test]
fn sqn_explicit_vs_twoloop_same_trajectory_quality() {
    if !artifacts_built() {
        eprintln!("[skip] artifacts not built");
        return;
    }
    let mut coord = Coordinator::new("artifacts", &results_dir()).unwrap();
    let mut finals = Vec::new();
    for mode in [HessianMode::Explicit, HessianMode::TwoLoop] {
        let spec = ExperimentSpec::new(TaskKind::Classification, BackendKind::Xla)
            .size(64)
            .epochs(80)
            .replications(2)
            .seed(105)
            .hessian(mode);
        finals.push(coord.run(&spec).unwrap().final_obj_stats().mean());
    }
    assert!((finals[0] - finals[1]).abs() < 0.05,
            "explicit {} vs twoloop {}", finals[0], finals[1]);
}
