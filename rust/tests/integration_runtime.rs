//! Runtime integration: load real AOT artifacts, execute them through PJRT,
//! and check the numerics against the native implementations.
//!
//! These tests need `artifacts/` (run `make artifacts` first); they are
//! skipped gracefully when it is missing so `cargo test` works in a fresh
//! checkout.

use simopt::backend::native::{NativeLr, NativeMode, NativeMv, NativeNv};
use simopt::backend::xla::{XlaLr, XlaMv, XlaNv};
use simopt::backend::{HessianMode, LrBackend, MvBackend, NvBackend};
use simopt::rng::StreamTree;
use simopt::runtime::{Arg, Engine};
use simopt::sim::{AssetUniverse, ClassifyData, NewsvendorInstance};
use simopt::tasks::CorrectionMemory;

fn engine() -> Option<Engine> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("[skip] artifacts not built");
        return None;
    }
    match Engine::new("artifacts") {
        Ok(e) => Some(e),
        Err(e) => {
            // e.g. the workspace is linked against the in-tree `xla` stub
            // (no PJRT runtime); CI without a real xla_extension stays green
            eprintln!("[skip] PJRT engine unavailable: {:#}", e);
            None
        }
    }
}

#[test]
fn manifest_lists_all_entries() {
    let Some(engine) = engine() else { return };
    for entry in ["mv_epoch", "mv_grad_step", "nv_grad", "lr_grad", "lr_hvp",
                  "lr_hbuild", "lr_happly", "lr_dir_twoloop"] {
        let key = if entry.starts_with("lr") { "n" } else { "d" };
        assert!(
            !engine.manifest.available_params(entry, key).is_empty(),
            "no artifacts for entry {}",
            entry
        );
    }
}

#[test]
fn compile_cache_reuses_executables() {
    let Some(engine) = engine() else { return };
    let d = engine.manifest.available_params("mv_epoch", "d")[0];
    let a = engine.load_by_params("mv_epoch", &[("d", d)]).unwrap();
    let before = engine.cached();
    let b = engine.load_by_params("mv_epoch", &[("d", d)]).unwrap();
    assert_eq!(engine.cached(), before);
    assert!(std::rc::Rc::ptr_eq(&a, &b));
}

#[test]
fn mv_epoch_artifact_outputs_valid_simplex_iterate() {
    let Some(engine) = engine() else { return };
    let d = engine.manifest.available_params("mv_epoch", "d")[0] as usize;
    let tree = StreamTree::new(5);
    let universe = AssetUniverse::generate(&tree, d);
    let mut xla = XlaMv::new(&engine, &universe, 64, 25).unwrap();
    let w0 = vec![1.0f32 / d as f32; d];
    let (w1, obj) = xla.epoch(&w0, 0, [1, 2]).unwrap();
    assert_eq!(w1.len(), d);
    assert!(simopt::tasks::mean_variance::in_simplex(&w1, 1e-4));
    assert!(obj.is_finite());
    // deterministic per key
    let (w2, obj2) = xla.epoch(&w0, 0, [1, 2]).unwrap();
    assert_eq!(w1, w2);
    assert_eq!(obj, obj2);
    // a different key samples a different panel: the empirical objective
    // estimate must differ (the iterate itself may converge to the same
    // vertex — asset σ ≤ 0.025 is small next to the μ spread)
    let (_, obj3) = xla.epoch(&w0, 0, [1, 3]).unwrap();
    assert_ne!(obj, obj3);
}

#[test]
fn mv_backends_agree_statistically() {
    // Same algorithm, same schedule, different RNG realizations: after a few
    // epochs both arms should reach similar exact objectives.
    let Some(engine) = engine() else { return };
    let d = engine.manifest.available_params("mv_epoch", "d")[0] as usize;
    let tree = StreamTree::new(6);
    let universe = AssetUniverse::generate(&tree, d);
    let w0 = vec![1.0f32 / d as f32; d];
    let mut xla = XlaMv::new(&engine, &universe, 64, 25).unwrap();
    let mut native = NativeMv::new(universe.clone(), 64, 25,
                                   NativeMode::Sequential);
    let sub = tree.subtree(&[0]);
    let (wx, _) = simopt::opt::run_mv(&mut xla, w0.clone(), 8, &sub).unwrap();
    let (wn, _) = simopt::opt::run_mv(&mut native, w0, 8, &sub).unwrap();
    let ox = universe.exact_objective(&wx);
    let on = universe.exact_objective(&wn);
    assert!(
        (ox - on).abs() < 0.05 * on.abs().max(0.01),
        "exact objectives diverge: xla {} vs native {}",
        ox,
        on
    );
}

#[test]
fn nv_grad_artifact_matches_native_bounds_and_stats() {
    let Some(engine) = engine() else { return };
    let d = engine.manifest.available_params("nv_grad", "d")[0] as usize;
    let tree = StreamTree::new(7);
    let inst = NewsvendorInstance::generate(&tree, d, 4, 0.6);
    let mut xla = XlaNv::new(&engine, &inst, 32).unwrap();
    let x = inst.feasible_start();
    let (g, obj) = xla.grad_obj(&x, [3, 4]).unwrap();
    assert_eq!(g.len(), d);
    assert!(obj.is_finite() && obj > 0.0);
    // gradient bracketed by the cost structure (CDF ∈ [0,1])
    for j in 0..d {
        assert!(g[j] >= inst.k[j] - inst.v[j] - 1e-4);
        assert!(g[j] <= inst.k[j] + inst.h[j] + 1e-4);
    }
    // statistical agreement with the native estimate at the same point
    let mut native = NativeNv::new(inst.clone(), 32, NativeMode::Sequential);
    let (gn, objn) = native.grad_obj(&x, [3, 4]).unwrap();
    let mean_diff: f64 = g
        .iter()
        .zip(&gn)
        .map(|(a, b)| (a - b).abs() as f64)
        .sum::<f64>()
        / d as f64;
    // different RNG realizations of a 32-sample CDF estimate: the indicator
    // mean has sd ≈ 0.5/√32 ≈ 0.09, scaled by (h+v) ≈ 5
    assert!(mean_diff < 1.0, "mean |Δg| too large: {}", mean_diff);
    assert!((obj - objn).abs() / objn.abs() < 0.05,
            "objectives diverge: {} vs {}", obj, objn);
}

#[test]
fn lr_grad_artifact_matches_native_exactly() {
    // Identical batch (CRN) ⇒ the two arms compute the same mathematical
    // function; agreement is up to float reassociation only.
    let Some(engine) = engine() else { return };
    let n = engine.manifest.available_params("lr_grad", "n")[0] as usize;
    let tree = StreamTree::new(8);
    let data = ClassifyData::generate(&tree, n);
    let mut xla = XlaLr::new(&engine, &data, 64, 256, 25,
                             HessianMode::Explicit).unwrap();
    let mut native = NativeLr::new(&data, NativeMode::Sequential,
                                   HessianMode::Explicit);
    let w: Vec<f32> = (0..n).map(|i| ((i * 7 % 13) as f32 - 6.0) * 0.02).collect();
    let idx: Vec<usize> = (0..64).map(|i| i * 3 % data.n_samples).collect();
    let (gx, lx) = xla.grad(&w, &data, &idx).unwrap();
    let (gn, ln) = native.grad(&w, &data, &idx).unwrap();
    assert!((lx - ln).abs() < 1e-4, "loss {} vs {}", lx, ln);
    for j in 0..n {
        assert!((gx[j] - gn[j]).abs() < 1e-4, "g[{}]: {} vs {}", j, gx[j], gn[j]);
    }
}

#[test]
fn lr_hvp_and_directions_match_native() {
    let Some(engine) = engine() else { return };
    let n = engine.manifest.available_params("lr_hvp", "n")[0] as usize;
    let tree = StreamTree::new(9);
    let data = ClassifyData::generate(&tree, n);
    let mut xla = XlaLr::new(&engine, &data, 64, 256, 25,
                             HessianMode::Explicit).unwrap();
    let mut native = NativeLr::new(&data, NativeMode::Sequential,
                                   HessianMode::Explicit);
    let w: Vec<f32> = (0..n).map(|i| (i as f32 * 0.1).sin() * 0.1).collect();
    let s: Vec<f32> = (0..n).map(|i| (i as f32 * 0.3).cos() * 0.05).collect();
    let idx: Vec<usize> = (0..256).map(|i| i * 5 % data.n_samples).collect();
    let yx = xla.hvp(&w, &s, &data, &idx).unwrap();
    let yn = native.hvp(&w, &s, &data, &idx).unwrap();
    // host-gathered rows for the raw-kernel correction pairs below
    let mut xh = Vec::new();
    let mut zh = Vec::new();
    data.gather(&idx, &mut xh, &mut zh);
    let _ = &zh;
    for j in 0..n {
        assert!((yx[j] - yn[j]).abs() < 1e-4, "y[{}]: {} vs {}", j, yx[j], yn[j]);
    }

    // correction memory with positive curvature
    let mut mem = CorrectionMemory::new(25, n);
    for t in 0..4 {
        let sv: Vec<f32> = (0..n)
            .map(|i| ((i + t) as f32 * 0.17).sin() * 0.05)
            .collect();
        let yv = {
            let mut out = vec![0.0f32; n];
            simopt::tasks::classification::hvp(&w, &sv, &xh, &mut out);
            // regularize so curvature is safely positive for the test
            for (o, svj) in out.iter_mut().zip(&sv) {
                *o += 0.01 * svj;
            }
            out
        };
        mem.push(&sv, &yv);
    }
    assert!(mem.count >= 2);
    let g: Vec<f32> = (0..n).map(|i| (i as f32 * 0.7).sin()).collect();
    let dx = xla.direction(&mem, &g).unwrap();
    let dn = native.direction(&mem, &g).unwrap();
    for j in 0..n {
        assert!((dx[j] - dn[j]).abs() < 2e-2 * (1.0 + dn[j].abs()),
                "d[{}]: {} vs {}", j, dx[j], dn[j]);
    }

    // two-loop mode agrees with explicit mode
    let mut xla2 = XlaLr::new(&engine, &data, 64, 256, 25,
                              HessianMode::TwoLoop).unwrap();
    let d2 = xla2.direction(&mem, &g).unwrap();
    for j in 0..n {
        assert!((d2[j] - dn[j]).abs() < 2e-2 * (1.0 + dn[j].abs()),
                "twoloop d[{}]: {} vs {}", j, d2[j], dn[j]);
    }
}

/// `unwrap_err` without requiring `Debug` on the success type (xla Literals
/// are not `Debug`).
fn expect_err<T>(r: anyhow::Result<T>) -> anyhow::Error {
    match r {
        Ok(_) => panic!("expected an error"),
        Err(e) => e,
    }
}

#[test]
fn shape_mismatch_rejected_cleanly() {
    let Some(engine) = engine() else { return };
    let d = engine.manifest.available_params("mv_epoch", "d")[0];
    let exec = engine.load_by_params("mv_epoch", &[("d", d)]).unwrap();
    let wrong = vec![0.0f32; 3];
    let key = [0u32, 0];
    // wrong vector length
    let err = expect_err(exec.call(&[
        Arg::F32(&wrong),
        Arg::F32(&wrong),
        Arg::F32(&wrong),
        Arg::U32(&key),
        Arg::ScalarI32(0),
    ]));
    assert!(err.to_string().contains("elements"), "{}", err);
    // wrong arity
    let err = expect_err(exec.call(&[Arg::F32(&wrong)]));
    assert!(err.to_string().contains("inputs"), "{}", err);
    // wrong dtype (f32 where the key's u32 belongs)
    let w = vec![0.0f32; d as usize];
    let err = expect_err(exec.call(&[
        Arg::F32(&w),
        Arg::F32(&w),
        Arg::F32(&w),
        Arg::F32(&w[..2]),
        Arg::ScalarI32(0),
    ]));
    assert!(err.to_string().contains("expects"), "{}", err);
}

#[test]
fn missing_artifact_has_actionable_error() {
    let Some(engine) = engine() else { return };
    let err = expect_err(engine.load_by_params("mv_epoch", &[("d", 999_983)]));
    let msg = format!("{:#}", err);
    assert!(msg.contains("mv_epoch"), "{}", msg);
    assert!(msg.contains("999983"), "{}", msg);
}
