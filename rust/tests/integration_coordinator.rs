//! Coordinator-level integration: sweeps, report generation, artifact
//! preflight, and failure injection (no artifacts needed for most).

use simopt::config::{BackendKind, ExecMode, TaskKind};
use simopt::coordinator::{report, Coordinator, ExperimentSpec, SweepSpec};

fn tmpdir(name: &str) -> String {
    let dir = std::env::temp_dir().join(name);
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir.to_string_lossy().into_owned()
}

#[test]
fn native_sweep_produces_full_grid_and_report() {
    let results_dir = tmpdir("simopt_coord_sweep");
    let mut coord = Coordinator::new("artifacts", &results_dir).unwrap();
    let sweep = SweepSpec {
        task: TaskKind::MeanVariance,
        sizes: vec![16, 32],
        backends: vec![BackendKind::Native],
        reps: 2,
        epochs: 3,
        seed: 9,
        exec: ExecMode::Auto,
    };
    let results = coord.sweep(&sweep).unwrap();
    assert_eq!(results.len(), 2);
    report::write_report(&results_dir, "test", &results, &[0.5, 1.0]).unwrap();
    let fig2 = std::fs::read_to_string(
        std::path::Path::new(&results_dir).join("test_fig2.md")).unwrap();
    assert!(fig2.contains("| 16 |"));
    assert!(fig2.contains("| 32 |"));
    let csv = std::fs::read_to_string(
        std::path::Path::new(&results_dir).join("test_summary.csv")).unwrap();
    assert_eq!(csv.lines().count(), 3); // header + 2 rows
}

#[test]
fn timing_grows_with_size() {
    let results_dir = tmpdir("simopt_coord_scaling");
    let mut coord = Coordinator::new("artifacts", &results_dir).unwrap();
    let small = ExperimentSpec::new(TaskKind::MeanVariance, BackendKind::Native)
        .size(32)
        .epochs(4)
        .replications(2)
        .seed(3);
    let large = small.clone().size(512);
    let t_small = coord.run(&small).unwrap().time_stats().mean();
    let t_large = coord.run(&large).unwrap().time_stats().mean();
    assert!(
        t_large > t_small,
        "16× dimension must cost more: {} vs {}",
        t_large,
        t_small
    );
}

#[test]
fn xla_without_artifacts_dir_fails_actionably() {
    let results_dir = tmpdir("simopt_coord_noart");
    let mut coord =
        Coordinator::new("/nonexistent/artifact/dir", &results_dir).unwrap();
    let spec = ExperimentSpec::new(TaskKind::MeanVariance, BackendKind::Xla)
        .epochs(1)
        .replications(1);
    let err = coord.run(&spec).unwrap_err();
    let msg = format!("{:#}", err);
    assert!(msg.contains("make artifacts"), "unhelpful error: {}", msg);
}

#[test]
fn native_par_backend_runs() {
    let results_dir = tmpdir("simopt_coord_par");
    let mut coord = Coordinator::new("artifacts", &results_dir).unwrap();
    let spec = ExperimentSpec::new(TaskKind::MeanVariance, BackendKind::NativePar)
        .size(64)
        .epochs(3)
        .replications(2)
        .seed(5);
    let res = coord.run(&spec).unwrap();
    assert_eq!(res.reps.len(), 2);
    assert!(res.reps.iter().all(|r| r.objs.iter().all(|o| o.is_finite())));
}

#[test]
fn replications_are_independent_but_reproducible() {
    let results_dir = tmpdir("simopt_coord_repro");
    let mut coord = Coordinator::new("artifacts", &results_dir).unwrap();
    let spec = ExperimentSpec::new(TaskKind::Newsvendor, BackendKind::Native)
        .size(32)
        .epochs(3)
        .replications(3)
        .seed(7);
    let a = coord.run(&spec).unwrap();
    let b = coord.run(&spec).unwrap();
    for (ra, rb) in a.reps.iter().zip(&b.reps) {
        assert_eq!(ra.objs, rb.objs);
    }
    // different reps differ (independent streams)
    assert_ne!(a.reps[0].objs, a.reps[1].objs);
    // different seed ⇒ different trajectories
    let c = coord.run(&spec.clone().seed(8)).unwrap();
    assert_ne!(a.reps[0].objs, c.reps[0].objs);
}

#[test]
fn classification_track_every_controls_checkpoints() {
    let results_dir = tmpdir("simopt_coord_track");
    let mut coord = Coordinator::new("artifacts", &results_dir).unwrap();
    let mut spec = ExperimentSpec::new(TaskKind::Classification,
                                       BackendKind::Native)
        .size(16)
        .epochs(40)
        .replications(1)
        .seed(1);
    spec.params.batch = 16;
    spec.params.hbatch = 32;
    spec.track_every = 10;
    let res = coord.run(&spec).unwrap();
    // checkpoints at k = 1, 10, 20, 30, 40
    assert_eq!(res.reps[0].objs.len(), 5);
    assert_eq!(res.reps[0].obj_iters, vec![1, 10, 20, 30, 40]);
}
